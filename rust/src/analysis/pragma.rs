//! Parsing and scoping of inline lint-allow pragmas.
//!
//! Grammar (one comment, exact shape — anything else starting with the
//! `lint:` marker is a malformed-pragma violation):
//!
//! ```text
//! // lint: allow(RULE_ID) reason="non-empty justification"
//! ```
//!
//! Scope:
//!
//! - **trailing** (code precedes it on the line): covers that line only;
//! - **standalone** above a line that begins a `fn` item: covers the whole
//!   function body (brace-matched);
//! - **standalone** above any other line: covers that next code line only.
//!
//! Unknown rule ids are a hard error, not a silent no-op — a typo'd
//! pragma must fail loudly (mirroring `obs/failpoint.rs`, where an
//! unknown site name is a structured error).

use super::lexer::{Comment, Lexed};

/// One successfully parsed pragma with its resolved line coverage.
#[derive(Clone, Debug)]
pub struct Pragma {
    pub rule: String,
    pub reason: String,
    /// Line of the pragma comment itself.
    pub line: u32,
    /// First and last covered line (inclusive).
    pub start: u32,
    pub end: u32,
}

/// A pragma that failed to parse or names a rule that does not exist.
#[derive(Clone, Debug)]
pub enum PragmaError {
    Malformed { line: u32, detail: String },
    UnknownRule { line: u32, rule: String },
}

/// Collect every pragma in a lexed file, resolving scopes against the
/// token stream. `known_rules` is the registered rule-id table.
pub fn collect(lx: &Lexed, known_rules: &[&str]) -> (Vec<Pragma>, Vec<PragmaError>) {
    let mut pragmas = Vec::new();
    let mut errors = Vec::new();
    for c in &lx.comments {
        let Some(rest) = c.text.strip_prefix("lint:") else { continue };
        match parse_body(rest.trim()) {
            Err(detail) => errors.push(PragmaError::Malformed { line: c.line, detail }),
            Ok((rule, reason)) => {
                if !known_rules.contains(&rule.as_str()) {
                    errors.push(PragmaError::UnknownRule { line: c.line, rule });
                    continue;
                }
                let (start, end) = scope_of(lx, c);
                pragmas.push(Pragma { rule, reason, line: c.line, start, end });
            }
        }
    }
    (pragmas, errors)
}

/// Parse `allow(RULE_ID) reason="…"` (the part after the `lint:` marker).
fn parse_body(s: &str) -> Result<(String, String), String> {
    let Some(rest) = s.strip_prefix("allow(") else {
        return Err("expected `allow(RULE_ID)`".to_string());
    };
    let Some(close) = rest.find(')') else {
        return Err("unclosed `allow(`".to_string());
    };
    let rule = rest[..close].trim().to_string();
    let id_ok = !rule.is_empty()
        && rule.bytes().all(|b| b.is_ascii_uppercase() || b.is_ascii_digit() || b == b'_');
    if !id_ok {
        return Err(format!("`{rule}` is not a rule id (UPPER_SNAKE_CASE)"));
    }
    let tail = rest[close + 1..].trim();
    let Some(r) = tail.strip_prefix("reason=\"") else {
        return Err("missing `reason=\"…\"`".to_string());
    };
    let Some(endq) = r.rfind('"') else {
        return Err("unclosed reason string".to_string());
    };
    let reason = r[..endq].trim().to_string();
    if reason.is_empty() {
        return Err("reason must be non-empty".to_string());
    }
    Ok((rule, reason))
}

/// Resolve the line range a pragma covers (see module docs).
fn scope_of(lx: &Lexed, c: &Comment) -> (u32, u32) {
    if c.trailing {
        return (c.line, c.line);
    }
    let t = &lx.tokens;
    let Some(first) = t.iter().position(|tk| tk.line > c.line) else {
        return (c.line, c.line);
    };
    let target = t[first].line;
    let line_has_fn = t[first..]
        .iter()
        .take_while(|tk| tk.line == target)
        .any(|tk| tk.ident("fn"));
    if line_has_fn {
        let mut j = first;
        while j < t.len() && !t[j].punct('{') {
            j += 1;
        }
        let mut depth = 0i32;
        while j < t.len() {
            if t[j].punct('{') {
                depth += 1;
            } else if t[j].punct('}') {
                depth -= 1;
                if depth == 0 {
                    return (c.line, t[j].line);
                }
            }
            j += 1;
        }
    }
    (c.line, target)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::lexer::lex;

    const KNOWN: &[&str] = &["PANIC_UNWRAP", "PANIC_INDEX"];

    #[test]
    fn trailing_pragma_covers_its_line() {
        let src = "fn f() {\n    let x = v.pop().unwrap(); // lint: allow(PANIC_UNWRAP) reason=\"checked\"\n}\n";
        let (p, e) = collect(&lex(src), KNOWN);
        assert!(e.is_empty());
        assert_eq!((p[0].start, p[0].end), (2, 2));
    }

    #[test]
    fn standalone_pragma_covers_next_line() {
        let src = "fn f() {\n    // lint: allow(PANIC_UNWRAP) reason=\"checked\"\n    let x = v.pop().unwrap();\n    let y = v.pop().unwrap();\n}\n";
        let (p, _) = collect(&lex(src), KNOWN);
        assert_eq!((p[0].start, p[0].end), (2, 3));
    }

    #[test]
    fn fn_pragma_covers_whole_body() {
        let src = "// lint: allow(PANIC_INDEX) reason=\"bounds pre-checked\"\npub fn pick(v: &[u32], i: usize) -> u32 {\n    if i > 0 {\n        v[i]\n    } else {\n        v[0]\n    }\n}\n";
        let (p, _) = collect(&lex(src), KNOWN);
        assert_eq!((p[0].start, p[0].end), (1, 8));
    }

    #[test]
    fn unknown_rule_is_an_error() {
        let src = "// lint: allow(PANIC_UNWRP) reason=\"typo\"\nfn f() {}\n";
        let (p, e) = collect(&lex(src), KNOWN);
        assert!(p.is_empty());
        assert!(matches!(&e[0], PragmaError::UnknownRule { rule, .. } if rule == "PANIC_UNWRP"));
    }

    #[test]
    fn malformed_pragmas_are_errors() {
        for bad in [
            "// lint: allow(PANIC_UNWRAP)\nfn f() {}\n",
            "// lint: allow(PANIC_UNWRAP) reason=\"\"\nfn f() {}\n",
            "// lint: allowing stuff\nfn f() {}\n",
        ] {
            let (p, e) = collect(&lex(bad), KNOWN);
            assert!(p.is_empty(), "{bad}");
            assert!(matches!(&e[0], PragmaError::Malformed { .. }), "{bad}");
        }
    }

    #[test]
    fn ordinary_comments_are_ignored() {
        let (p, e) = collect(&lex("// the linter counts allow pragmas\nfn f() {}\n"), KNOWN);
        assert!(p.is_empty() && e.is_empty());
    }
}
