//! Markdown-side extractors: the documented half of each cross-file
//! contract (`API.md` §2 slugs, §8 metric series, the README flag tables
//! and failpoint-site mentions).

use std::path::Path;

/// Everything `armor lint` needs from the two contract documents.
#[derive(Clone, Debug, Default)]
pub struct DocFacts {
    /// Metric series names in API.md §8, with the 1-based line of first
    /// mention.
    pub api_metrics: Vec<(u32, String)>,
    /// Reason slugs from the API.md §2 `Slugs in v1:` list.
    pub api_slugs: Vec<(u32, String)>,
    /// Full API.md text with backticks stripped — the haystack for
    /// `"<status> <slug>"` envelope-pair checks.
    pub api_flat: String,
    /// Flag names from README `| `--flag …` |` table rows, with line.
    pub readme_flags: Vec<(u32, String)>,
    /// Raw README text — the haystack for failpoint-site mentions.
    pub readme_text: String,
}

impl DocFacts {
    /// Load and extract from `<root>/API.md` and `<root>/README.md`.
    pub fn load(root: &Path) -> crate::Result<DocFacts> {
        let api = std::fs::read_to_string(root.join("API.md"))
            .map_err(|e| crate::err!("lint: reading API.md under {}: {e}", root.display()))?;
        let readme = std::fs::read_to_string(root.join("README.md"))
            .map_err(|e| crate::err!("lint: reading README.md under {}: {e}", root.display()))?;
        Ok(DocFacts {
            api_metrics: section_metric_names(&api),
            api_slugs: slug_list(&api),
            api_flat: api.replace('`', ""),
            readme_flags: flag_table_rows(&readme),
            readme_text: readme,
        })
    }
}

/// `armor_*` series names inside the `## 8.` section of API.md (scoping
/// to §8 keeps incidental mentions elsewhere out of the contract).
fn section_metric_names(api: &str) -> Vec<(u32, String)> {
    let mut out: Vec<(u32, String)> = Vec::new();
    let mut in_s8 = false;
    for (idx, line) in api.lines().enumerate() {
        if line.starts_with("## ") {
            in_s8 = line.starts_with("## 8");
            continue;
        }
        if !in_s8 {
            continue;
        }
        for name in armor_names(line) {
            if !out.iter().any(|(_, n)| *n == name) {
                out.push((idx as u32 + 1, name));
            }
        }
    }
    out
}

/// Scan one line for `armor_<lowercase/digit/underscore>+` names.
fn armor_names(s: &str) -> Vec<String> {
    let b = s.as_bytes();
    let mut out = Vec::new();
    let mut i = 0usize;
    while let Some(p) = s[i..].find("armor_") {
        let start = i + p;
        let boundary =
            start == 0 || !(b[start - 1].is_ascii_alphanumeric() || b[start - 1] == b'_');
        let mut e = start + "armor_".len();
        while e < b.len() && (b[e].is_ascii_lowercase() || b[e].is_ascii_digit() || b[e] == b'_') {
            e += 1;
        }
        // Require at least one body character: prose like `armor_*_us`
        // names a family, not a series.
        if boundary && e > start + "armor_".len() {
            out.push(s[start..e].to_string());
        }
        i = e.max(start + 1);
    }
    out
}

/// The §2 reason-slug list: backticked tokens between `Slugs in v1:` and
/// the sentence-ending period.
fn slug_list(api: &str) -> Vec<(u32, String)> {
    let lines: Vec<&str> = api.lines().collect();
    let Some(start) = lines.iter().position(|l| l.contains("Slugs in v1:")) else {
        return Vec::new();
    };
    let mut out = Vec::new();
    let mut in_tick = false;
    let mut token = String::new();
    for (idx, line) in lines.iter().enumerate().skip(start) {
        let text = if idx == start {
            let at = line.find("Slugs in v1:").map(|p| p + "Slugs in v1:".len());
            &line[at.unwrap_or(0)..]
        } else {
            line
        };
        for ch in text.chars() {
            match ch {
                '`' => {
                    if in_tick && !token.is_empty() {
                        let ok = token.chars().next().is_some_and(|c| c.is_ascii_lowercase())
                            && token.chars().all(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || c == '_');
                        if ok {
                            out.push((idx as u32 + 1, token.clone()));
                        }
                    }
                    token.clear();
                    in_tick = !in_tick;
                }
                '.' if !in_tick => return out, // end of the list sentence
                c if in_tick => token.push(c),
                _ => {}
            }
        }
    }
    out
}

/// Flag names from README table rows of the form `| `--name …` | … |`.
fn flag_table_rows(readme: &str) -> Vec<(u32, String)> {
    let mut out: Vec<(u32, String)> = Vec::new();
    for (idx, line) in readme.lines().enumerate() {
        let t = line.trim_start();
        let Some(rest) = t.strip_prefix("| `--") else { continue };
        let name: String = rest
            .chars()
            .take_while(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || *c == '-')
            .collect();
        if !name.is_empty() && !out.iter().any(|(_, n)| *n == name) {
            out.push((idx as u32 + 1, name));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn metric_names_scope_to_section_8() {
        let api = "# t\n## 7. Other\n`armor_elsewhere_total`\n## 8. `GET /metrics`\ncounters `armor_requests_total` and\n`armor_step_us{plane=\"f32\"}`; families like `armor_*_total` are prose.\n## 9. Next\n`armor_after_total`\n";
        let got = section_metric_names(api);
        let names: Vec<&str> = got.iter().map(|(_, n)| n.as_str()).collect();
        assert_eq!(names, vec!["armor_requests_total", "armor_step_us"]);
        assert_eq!(got[0].0, 5);
    }

    #[test]
    fn slug_list_stops_at_sentence_end() {
        let api = "## 2. Errors\nSlugs in v1: `bad_request`,\n`overloaded`. The `code` field repeats the status.\n";
        let got = slug_list(api);
        let names: Vec<&str> = got.iter().map(|(_, n)| n.as_str()).collect();
        assert_eq!(names, vec!["bad_request", "overloaded"]);
        assert_eq!(got[0].0, 2);
        assert_eq!(got[1].0, 3);
    }

    #[test]
    fn flag_rows_parse() {
        let md = "| Flag | Default |\n| `--batch N` | 8 |\n| `--quant off\\|q8` | off |\nnot a row `--ghost`\n";
        let got = flag_table_rows(md);
        let names: Vec<&str> = got.iter().map(|(_, n)| n.as_str()).collect();
        assert_eq!(names, vec!["batch", "quant"]);
    }
}
