//! The rule engine: walks `rust/src`, lexes every file, applies the four
//! rule families, and cross-checks code facts against the contract
//! documents. See DESIGN.md §12 for the contract each rule pins.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use super::docs::DocFacts;
use super::extract;
use super::lexer::{lex, test_regions};
use super::pragma::{self, Pragma, PragmaError};
use super::report::{LintReport, PragmaUse, Violation};
use super::RULES;

/// Files where the engine-worker panic-freedom rules apply: a panic on
/// the `armor-engine` thread kills every in-flight stream, and the
/// metrics registry sits on that same hot path.
const PANIC_SCOPE: &[&str] = &[
    "rust/src/serve/engine.rs",
    "rust/src/serve/service.rs",
    "rust/src/serve/scheduler.rs",
    "rust/src/serve/kv_pool.rs",
    "rust/src/serve/kv_cache.rs",
    "rust/src/serve/prefix.rs",
    "rust/src/obs/registry.rs",
];

/// Directory prefixes whose `MetricsRegistry` registrations participate
/// in the API.md §8 exposition contract. `util/timer.rs` registers on the
/// process-global registry (not the engine registry `/metrics` exposes)
/// and stays out.
const METRIC_SCOPE: &[&str] = &["rust/src/serve/", "rust/src/obs/", "rust/src/model/"];

/// Files whose `(status, slug)` literals participate in the API.md §2
/// envelope contract.
const SLUG_SCOPE: &[&str] = &[
    "rust/src/serve/http/handlers.rs",
    "rust/src/serve/http/server.rs",
    "rust/src/serve/http/parser.rs",
];

/// Run every rule over the repository rooted at `root`.
pub fn run(root: &Path) -> crate::Result<LintReport> {
    let docs = DocFacts::load(root)?;
    let src_root = root.join("rust").join("src");
    crate::ensure!(
        src_root.is_dir(),
        "lint: {} is not a repo root (missing rust/src)",
        root.display()
    );
    let mut paths = Vec::new();
    walk_rs(&src_root, &mut paths)?;

    let rule_ids: Vec<&str> = RULES.iter().map(|r| r.0).collect();
    let mut report = LintReport { files_scanned: paths.len(), ..LintReport::default() };
    // Cross-file fact accumulators: (path, line, fact).
    let mut registered: BTreeMap<String, (String, u32)> = BTreeMap::new();
    let mut code_slugs: Vec<(String, u32, u16, String)> = Vec::new();
    let mut failpoints: Vec<(String, u32, String)> = Vec::new();
    let mut flags: BTreeMap<String, (String, u32)> = BTreeMap::new();

    for path in &paths {
        let rel = rel_path(root, path);
        let src = std::fs::read_to_string(path)
            .map_err(|e| crate::err!("lint: reading {rel}: {e}"))?;
        let lx = lex(&src);
        let tests = test_regions(&lx);
        let (pragmas, perrs) = pragma::collect(&lx, &rule_ids);
        let mut pused = vec![false; pragmas.len()];

        for e in &perrs {
            report.violations.push(match e {
                PragmaError::Malformed { line, detail } => Violation {
                    path: rel.clone(),
                    line: *line,
                    rule: "PRAGMA_MALFORMED",
                    message: format!("malformed allow pragma: {detail}"),
                    fix: "write `lint: allow(RULE_ID) reason=\"…\"` exactly".to_string(),
                },
                PragmaError::UnknownRule { line, rule } => Violation {
                    path: rel.clone(),
                    line: *line,
                    rule: "PRAGMA_UNKNOWN",
                    message: format!(
                        "pragma names unknown rule `{rule}` and suppresses nothing"
                    ),
                    fix: format!("use one of: {}", rule_ids.join(", ")),
                },
            });
        }

        if PANIC_SCOPE.contains(&rel.as_str()) {
            for (rule, line, what) in extract::panic_sites(&lx) {
                if extract::in_regions(&tests, line) || allowed(&pragmas, &mut pused, rule, line)
                {
                    continue;
                }
                let (message, fix) = match rule {
                    "PANIC_UNWRAP" => (
                        format!("`{what}` can panic the engine worker"),
                        "return crate::Result, or recover (poisoned locks: \
                         lock().unwrap_or_else(|p| p.into_inner())), or justify with an \
                         allow pragma"
                            .to_string(),
                    ),
                    "PANIC_MACRO" => (
                        format!("`{what}` can panic the engine worker"),
                        "return a structured error, or justify with an allow pragma"
                            .to_string(),
                    ),
                    _ => (
                        format!("`{what}` indexing can panic the engine worker"),
                        "use .get()/checked slicing, or a fn-scope allow pragma stating \
                         the bounds argument"
                            .to_string(),
                    ),
                };
                report.violations.push(Violation { path: rel.clone(), line, rule, message, fix });
            }
        }

        for line in extract::unsafe_sites(&lx) {
            if extract::in_regions(&tests, line) {
                continue;
            }
            let documented = lx
                .comments
                .iter()
                .any(|c| c.text.contains("SAFETY:") && c.line <= line && c.line + 3 >= line);
            if !documented && !allowed(&pragmas, &mut pused, "UNSAFE_SAFETY", line) {
                report.violations.push(Violation {
                    path: rel.clone(),
                    line,
                    rule: "UNSAFE_SAFETY",
                    message: "`unsafe` without a `// SAFETY:` comment on the preceding lines"
                        .to_string(),
                    fix: "state the invariant that makes this sound in a `// SAFETY:` comment \
                          directly above"
                        .to_string(),
                });
            }
        }

        if !rel.starts_with("rust/src/obs/") {
            for (line, ord) in extract::ordering_sites(&lx) {
                if extract::in_regions(&tests, line) {
                    continue;
                }
                let justified = lx
                    .comments
                    .iter()
                    .any(|c| !c.text.is_empty() && c.line <= line && c.line + 2 >= line);
                if !justified && !allowed(&pragmas, &mut pused, "ORDERING_COMMENT", line) {
                    report.violations.push(Violation {
                        path: rel.clone(),
                        line,
                        rule: "ORDERING_COMMENT",
                        message: format!(
                            "`Ordering::{ord}` without a justifying comment (same line or the \
                             two above)"
                        ),
                        fix: "say why this ordering is sufficient (what the atomic \
                              synchronizes, or why no ordering is needed)"
                            .to_string(),
                    });
                }
            }
        }

        if METRIC_SCOPE.iter().any(|d| rel.starts_with(d)) {
            for (line, name) in extract::metric_registrations(&lx) {
                if !extract::in_regions(&tests, line) {
                    registered.entry(name).or_insert((rel.clone(), line));
                }
            }
        }
        if SLUG_SCOPE.contains(&rel.as_str()) {
            for (line, status, slug) in extract::slug_sites(&lx) {
                if !extract::in_regions(&tests, line)
                    && !code_slugs.iter().any(|(_, _, st, sl)| *st == status && *sl == slug)
                {
                    code_slugs.push((rel.clone(), line, status, slug));
                }
            }
        }
        if rel == "rust/src/obs/failpoint.rs" {
            for (line, site) in extract::failpoint_sites(&lx) {
                failpoints.push((rel.clone(), line, site));
            }
        }
        if rel == "rust/src/main.rs" {
            for (line, name) in extract::flag_reads(&lx) {
                if !extract::in_regions(&tests, line) {
                    flags.entry(name).or_insert((rel.clone(), line));
                }
            }
        }

        for (k, p) in pragmas.iter().enumerate() {
            report.pragmas.push(PragmaUse {
                path: rel.clone(),
                line: p.line,
                rule: p.rule.clone(),
                reason: p.reason.clone(),
                used: pused[k],
            });
        }
    }

    drift_checks(&mut report, &docs, &registered, &code_slugs, &failpoints, &flags);

    report.violations.sort_by(|a, b| {
        (a.path.as_str(), a.line, a.rule).cmp(&(b.path.as_str(), b.line, b.rule))
    });
    Ok(report)
}

/// The cross-file half: code facts vs the contract documents.
fn drift_checks(
    report: &mut LintReport,
    docs: &DocFacts,
    registered: &BTreeMap<String, (String, u32)>,
    code_slugs: &[(String, u32, u16, String)],
    failpoints: &[(String, u32, String)],
    flags: &BTreeMap<String, (String, u32)>,
) {
    // Metrics: registered ↔ API.md §8, both directions.
    for (name, (path, line)) in registered {
        if !docs.api_metrics.iter().any(|(_, n)| n == name) {
            report.violations.push(Violation {
                path: path.clone(),
                line: *line,
                rule: "DRIFT_METRIC",
                message: format!("metric `{name}` is registered but not documented in API.md §8"),
                fix: format!("add `{name}` to the API.md §8 series table"),
            });
        }
    }
    for (line, name) in &docs.api_metrics {
        if !registered.contains_key(name) {
            report.violations.push(Violation {
                path: "API.md".to_string(),
                line: *line,
                rule: "DRIFT_METRIC",
                message: format!("metric `{name}` is documented in §8 but never registered"),
                fix: "register the series or drop it from the table".to_string(),
            });
        }
    }

    // Slugs: code (status, slug) pairs ↔ API.md §2, both directions.
    for (path, line, status, slug) in code_slugs {
        if !docs.api_slugs.iter().any(|(_, s)| s == slug) {
            report.violations.push(Violation {
                path: path.clone(),
                line: *line,
                rule: "DRIFT_SLUG",
                message: format!("reason slug `{slug}` is not in the API.md §2 slug list"),
                fix: format!("add `{slug}` to the `Slugs in v1:` list (or fix the call site)"),
            });
        } else if !docs.api_flat.contains(&format!("{status} {slug}")) {
            report.violations.push(Violation {
                path: path.clone(),
                line: *line,
                rule: "DRIFT_SLUG",
                message: format!("status/slug pair `{status} {slug}` is not documented in API.md"),
                fix: format!("document the `{status} {slug}` pairing in API.md"),
            });
        }
    }
    for (line, slug) in &docs.api_slugs {
        if !code_slugs.iter().any(|(_, _, _, s)| s == slug) {
            report.violations.push(Violation {
                path: "API.md".to_string(),
                line: *line,
                rule: "DRIFT_SLUG",
                message: format!("slug `{slug}` is documented in §2 but no handler emits it"),
                fix: "emit it from a handler or drop it from the list".to_string(),
            });
        }
    }

    // Failpoints: every site string must be mentioned in the README.
    for (path, line, site) in failpoints {
        if !docs.readme_text.contains(site.as_str()) {
            report.violations.push(Violation {
                path: path.clone(),
                line: *line,
                rule: "DRIFT_FAILPOINT",
                message: format!("failpoint site `{site}` is not mentioned in the README"),
                fix: "document the site in the README fault-injection section".to_string(),
            });
        }
    }

    // Flags: parsed in main.rs ↔ README flag tables, both directions.
    for (name, (path, line)) in flags {
        if !docs.readme_flags.iter().any(|(_, f)| f == name) {
            report.violations.push(Violation {
                path: path.clone(),
                line: *line,
                rule: "DRIFT_FLAG",
                message: format!("flag `--{name}` is parsed but missing from the README flag tables"),
                fix: format!("add a `--{name}` row to the matching README table"),
            });
        }
    }
    for (line, name) in &docs.readme_flags {
        if !flags.contains_key(name) {
            report.violations.push(Violation {
                path: "README.md".to_string(),
                line: *line,
                rule: "DRIFT_FLAG",
                message: format!("flag `--{name}` is documented but never parsed in main.rs"),
                fix: "parse the flag or drop the row".to_string(),
            });
        }
    }
}

/// Does a pragma of `rule` cover `line`? Marks the pragma used.
fn allowed(pragmas: &[Pragma], pused: &mut [bool], rule: &str, line: u32) -> bool {
    for (k, p) in pragmas.iter().enumerate() {
        if p.rule == rule && line >= p.start && line <= p.end {
            pused[k] = true;
            return true;
        }
    }
    false
}

/// Collect `.rs` files under `dir`, depth-first, sorted for determinism.
fn walk_rs(dir: &Path, out: &mut Vec<PathBuf>) -> crate::Result<()> {
    let mut entries: Vec<PathBuf> = std::fs::read_dir(dir)
        .map_err(|e| crate::err!("lint: reading {}: {e}", dir.display()))?
        .filter_map(|e| e.ok().map(|e| e.path()))
        .collect();
    entries.sort();
    for p in entries {
        if p.is_dir() {
            walk_rs(&p, out)?;
        } else if p.extension().and_then(|e| e.to_str()) == Some("rs") {
            out.push(p);
        }
    }
    Ok(())
}

/// Repo-relative path with forward slashes.
fn rel_path(root: &Path, path: &Path) -> String {
    path.strip_prefix(root)
        .unwrap_or(path)
        .to_string_lossy()
        .replace('\\', "/")
}
