//! Mini property-testing framework (proptest is unavailable offline).
//!
//! Seeded generators + a `forall` driver that reports the failing seed so a
//! failure reproduces with `ARMOR_PROP_SEED=<seed>`. Used by the integration
//! tests in `rust/tests/` for the coordinator/optimizer invariants.

use crate::tensor::Matrix;
use crate::util::rng::Pcg64;

/// Number of cases per property (`ARMOR_PROP_CASES` to override).
pub fn num_cases(default: usize) -> usize {
    std::env::var("ARMOR_PROP_CASES").ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// Run `prop` over `cases` generated inputs. On failure, panics with the
/// case's seed for reproduction.
pub fn forall<G, T, P>(name: &str, cases: usize, generate: G, prop: P)
where
    G: Fn(&mut Pcg64) -> T,
    P: Fn(&T) -> Result<(), String>,
{
    let base = std::env::var("ARMOR_PROP_SEED")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0xA4u64);
    for case in 0..cases {
        let seed = base.wrapping_add(case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Pcg64::seed_from_u64(seed);
        let input = generate(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!("property '{name}' failed on case {case} (seed {seed}): {msg}");
        }
    }
}

/// Generator helpers.
pub struct Gen;

impl Gen {
    /// Random matrix with dims sampled from `dims` (rows, cols both chosen
    /// from the list, cols forced to a multiple of `col_multiple`).
    pub fn matrix(rng: &mut Pcg64, dims: &[usize], col_multiple: usize) -> Matrix {
        let rows = dims[rng.next_below(dims.len() as u32) as usize];
        let mut cols = dims[rng.next_below(dims.len() as u32) as usize];
        cols = (cols / col_multiple).max(1) * col_multiple;
        let mut m = Matrix::randn(rows, cols, rng);
        // occasionally inject structure: zero columns, tiny values, outliers
        match rng.next_below(4) {
            0 => {
                let c = rng.next_below(cols as u32) as usize;
                for r in 0..rows {
                    m[(r, c)] = 0.0;
                }
            }
            1 => {
                let r = rng.next_below(rows as u32) as usize;
                for c in 0..cols {
                    m[(r, c)] *= 100.0;
                }
            }
            2 => {
                for x in m.data.iter_mut() {
                    *x *= 1e-3;
                }
            }
            _ => {}
        }
        m
    }

    /// Positive activation weights of length `n`, with occasional zeros.
    pub fn act_norms(rng: &mut Pcg64, n: usize) -> Vec<f32> {
        (0..n)
            .map(|_| {
                if rng.next_f32() < 0.05 {
                    0.0
                } else {
                    rng.next_f32() * 4.0 + 0.01
                }
            })
            .collect()
    }

    /// A valid block size for the given dims.
    pub fn block_size(rng: &mut Pcg64, rows: usize, cols: usize) -> usize {
        let mut candidates: Vec<usize> =
            [4usize, 8, 16].iter().copied().filter(|&b| rows % b == 0 && cols % b == 0).collect();
        if candidates.is_empty() {
            candidates.push(1);
        }
        candidates[rng.next_below(candidates.len() as u32) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_trivial() {
        forall("trivial", 10, |rng| rng.next_f32(), |x| {
            if (0.0..1.0).contains(x) {
                Ok(())
            } else {
                Err(format!("{x} out of range"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'fails'")]
    fn forall_reports_failure() {
        forall("fails", 5, |rng| rng.next_below(10), |&x| {
            if x > 10 {
                Ok(())
            } else {
                Err("always fails".into())
            }
        });
    }

    #[test]
    fn generators_produce_valid_shapes() {
        let mut rng = Pcg64::seed_from_u64(0);
        for _ in 0..20 {
            let m = Gen::matrix(&mut rng, &[8, 16, 32], 4);
            assert_eq!(m.cols % 4, 0);
            let db = Gen::block_size(&mut rng, m.rows, m.cols);
            assert_eq!(m.rows % db, 0);
            assert_eq!(m.cols % db, 0);
            let d = Gen::act_norms(&mut rng, m.cols);
            assert!(d.iter().all(|&x| x >= 0.0));
        }
    }
}
