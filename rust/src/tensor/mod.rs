//! Dense row-major matrix type and blocked views.
//!
//! All weight matrices in the library follow the paper's convention
//! `W ∈ R^{d_out × d_in}` (rows = output features). Block indexing uses the
//! paper's Appendix-A notation: `C^{(i,j)}` is the `d_block × d_block` block
//! at block-row `i`, block-col `j`.

mod matrix;
pub use matrix::Matrix;

/// A block-diagonal square matrix stored densely per block:
/// `blocks[i]` is the `d_block × d_block` block `D^{(i)}` (paper §3.1).
#[derive(Clone, Debug, PartialEq)]
pub struct BlockDiag {
    pub d: usize,
    pub d_block: usize,
    /// `d / d_block` blocks, each a row-major `d_block × d_block` matrix.
    pub blocks: Vec<Matrix>,
}

impl BlockDiag {
    /// Identity block-diagonal of size `d` with block size `d_block`.
    /// Panics unless `d_block` divides `d`.
    pub fn identity(d: usize, d_block: usize) -> BlockDiag {
        assert!(d_block > 0 && d % d_block == 0, "d_block {d_block} must divide d {d}");
        let n = d / d_block;
        BlockDiag { d, d_block, blocks: (0..n).map(|_| Matrix::eye(d_block)).collect() }
    }

    pub fn n_blocks(&self) -> usize {
        self.d / self.d_block
    }

    /// Number of stored (nonzero-capable) parameters: `n_blocks * d_block²`.
    pub fn param_count(&self) -> usize {
        self.n_blocks() * self.d_block * self.d_block
    }

    /// Densify into a full `d × d` matrix (for tests / small cases).
    pub fn to_dense(&self) -> Matrix {
        let mut out = Matrix::zeros(self.d, self.d);
        for (bi, blk) in self.blocks.iter().enumerate() {
            let off = bi * self.d_block;
            for r in 0..self.d_block {
                for c in 0..self.d_block {
                    out[(off + r, off + c)] = blk[(r, c)];
                }
            }
        }
        out
    }

    /// Left-apply: `self · m` where `m` is `d × k`. Each block multiplies its
    /// own row-panel — O(d · d_block · k) instead of O(d² k).
    pub fn matmul_right(&self, m: &Matrix) -> Matrix {
        assert_eq!(self.d, m.rows);
        let mut out = Matrix::zeros(m.rows, m.cols);
        for (bi, blk) in self.blocks.iter().enumerate() {
            let off = bi * self.d_block;
            for r in 0..self.d_block {
                let orow = off + r;
                for t in 0..self.d_block {
                    let a = blk[(r, t)];
                    if a == 0.0 {
                        continue;
                    }
                    let src = m.row(off + t);
                    let dst = out.row_mut(orow);
                    for c in 0..m.cols {
                        dst[c] += a * src[c];
                    }
                }
            }
        }
        out
    }

    /// Right-apply: `m · self` where `m` is `k × d`.
    pub fn matmul_left(&self, m: &Matrix) -> Matrix {
        assert_eq!(self.d, m.cols);
        let mut out = Matrix::zeros(m.rows, m.cols);
        for (bj, blk) in self.blocks.iter().enumerate() {
            let off = bj * self.d_block;
            for r in 0..m.rows {
                let src = m.row(r);
                let dst = out.row_mut(r);
                for t in 0..self.d_block {
                    let x = src[off + t];
                    if x == 0.0 {
                        continue;
                    }
                    for c in 0..self.d_block {
                        dst[off + c] += x * blk[(t, c)];
                    }
                }
            }
        }
        out
    }

    /// Apply to a vector from the left: `y = self · x`.
    pub fn matvec(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(x.len(), self.d);
        let mut y = vec![0.0f32; self.d];
        for (bi, blk) in self.blocks.iter().enumerate() {
            let off = bi * self.d_block;
            for r in 0..self.d_block {
                let mut acc = 0.0f32;
                let row = blk.row(r);
                for t in 0..self.d_block {
                    acc += row[t] * x[off + t];
                }
                y[off + r] = acc;
            }
        }
        y
    }

    /// Scale block rows by a per-global-row factor (used to fold the NoWag
    /// denormalization `r^{(2)}` into `A`).
    pub fn scale_rows(&mut self, s: &[f32]) {
        assert_eq!(s.len(), self.d);
        for (bi, blk) in self.blocks.iter_mut().enumerate() {
            let off = bi * self.d_block;
            for r in 0..self.d_block {
                let f = s[off + r];
                for c in 0..self.d_block {
                    blk[(r, c)] *= f;
                }
            }
        }
    }

    /// Scale block columns by a per-global-col factor (folds `r^{(1)}` into `B`).
    pub fn scale_cols(&mut self, s: &[f32]) {
        assert_eq!(s.len(), self.d);
        for (bj, blk) in self.blocks.iter_mut().enumerate() {
            let off = bj * self.d_block;
            for r in 0..self.d_block {
                for c in 0..self.d_block {
                    blk[(r, c)] *= s[off + c];
                }
            }
        }
    }

    /// Transpose (transposes each block).
    pub fn transpose(&self) -> BlockDiag {
        BlockDiag {
            d: self.d,
            d_block: self.d_block,
            blocks: self.blocks.iter().map(|b| b.transpose()).collect(),
        }
    }

    /// Frobenius-norm distance to another block-diagonal (tests).
    pub fn max_abs_diff(&self, other: &BlockDiag) -> f32 {
        assert_eq!(self.d, other.d);
        assert_eq!(self.d_block, other.d_block);
        self.blocks
            .iter()
            .zip(&other.blocks)
            .map(|(a, b)| a.max_abs_diff(b))
            .fold(0.0, f32::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn identity_acts_as_identity() {
        let mut rng = Pcg64::seed_from_u64(0);
        let a = BlockDiag::identity(8, 4);
        let m = Matrix::randn(8, 6, &mut rng);
        assert!(a.matmul_right(&m).max_abs_diff(&m) < 1e-7);
        let m2 = Matrix::randn(5, 8, &mut rng);
        assert!(a.matmul_left(&m2).max_abs_diff(&m2) < 1e-7);
    }

    #[test]
    fn blockdiag_matches_dense_multiply() {
        let mut rng = Pcg64::seed_from_u64(1);
        let mut bd = BlockDiag::identity(8, 4);
        for b in &mut bd.blocks {
            *b = Matrix::randn(4, 4, &mut rng);
        }
        let m = Matrix::randn(8, 5, &mut rng);
        let dense = bd.to_dense().matmul(&m);
        assert!(bd.matmul_right(&m).max_abs_diff(&dense) < 1e-5);

        let m2 = Matrix::randn(3, 8, &mut rng);
        let dense2 = m2.matmul(&bd.to_dense());
        assert!(bd.matmul_left(&m2).max_abs_diff(&dense2) < 1e-5);
    }

    #[test]
    fn matvec_matches_dense() {
        let mut rng = Pcg64::seed_from_u64(2);
        let mut bd = BlockDiag::identity(12, 4);
        for b in &mut bd.blocks {
            *b = Matrix::randn(4, 4, &mut rng);
        }
        let x: Vec<f32> = (0..12).map(|_| rng.next_gaussian()).collect();
        let xm = Matrix::from_vec(12, 1, x.clone());
        let want = bd.to_dense().matmul(&xm);
        let got = bd.matvec(&x);
        for i in 0..12 {
            assert!((got[i] - want[(i, 0)]).abs() < 1e-5);
        }
    }

    #[test]
    fn scale_rows_cols_match_dense_diag() {
        let mut rng = Pcg64::seed_from_u64(3);
        let mut bd = BlockDiag::identity(8, 2);
        for b in &mut bd.blocks {
            *b = Matrix::randn(2, 2, &mut rng);
        }
        let s: Vec<f32> = (0..8).map(|i| 0.5 + i as f32).collect();
        let dense = bd.to_dense();

        let mut rowscaled = bd.clone();
        rowscaled.scale_rows(&s);
        let mut want = dense.clone();
        for r in 0..8 {
            for c in 0..8 {
                want[(r, c)] *= s[r];
            }
        }
        assert!(rowscaled.to_dense().max_abs_diff(&want) < 1e-6);

        let mut colscaled = bd.clone();
        colscaled.scale_cols(&s);
        let mut want2 = dense;
        for r in 0..8 {
            for c in 0..8 {
                want2[(r, c)] *= s[c];
            }
        }
        assert!(colscaled.to_dense().max_abs_diff(&want2) < 1e-6);
    }

    #[test]
    #[should_panic]
    fn rejects_nondividing_block() {
        BlockDiag::identity(10, 4);
    }

    #[test]
    fn param_count_is_sublinear() {
        let bd = BlockDiag::identity(1024, 32);
        assert_eq!(bd.param_count(), 32 * 32 * 32);
        assert!(bd.param_count() < 1024 * 1024 / 10);
    }
}
