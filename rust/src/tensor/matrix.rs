//! Row-major dense f32 matrix.

use crate::util::rng::Pcg64;
use std::ops::{Index, IndexMut};

/// Dense row-major matrix of `f32`. The workhorse container for weights,
/// activations, masks-as-floats, and gradients.
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Matrix {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn ones(rows: usize, cols: usize) -> Matrix {
        Matrix { rows, cols, data: vec![1.0; rows * cols] }
    }

    pub fn eye(n: usize) -> Matrix {
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Matrix {
        assert_eq!(data.len(), rows * cols, "shape mismatch");
        Matrix { rows, cols, data }
    }

    pub fn from_fn(rows: usize, cols: usize, f: impl Fn(usize, usize) -> f32) -> Matrix {
        let mut data = Vec::with_capacity(rows * cols);
        for r in 0..rows {
            for c in 0..cols {
                data.push(f(r, c));
            }
        }
        Matrix { rows, cols, data }
    }

    /// Standard-normal entries.
    pub fn randn(rows: usize, cols: usize, rng: &mut Pcg64) -> Matrix {
        let data = (0..rows * cols).map(|_| rng.next_gaussian()).collect();
        Matrix { rows, cols, data }
    }

    /// Normal with given std.
    pub fn randn_scaled(rows: usize, cols: usize, std: f32, rng: &mut Pcg64) -> Matrix {
        let data = (0..rows * cols).map(|_| rng.next_gaussian() * std).collect();
        Matrix { rows, cols, data }
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    pub fn transpose(&self) -> Matrix {
        let mut out = Matrix::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            let src = self.row(r);
            for c in 0..self.cols {
                out.data[c * self.rows + r] = src[c];
            }
        }
        out
    }

    /// Dense matmul via the blocked/threaded kernel in `linalg`.
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        crate::linalg::gemm(self, other)
    }

    /// Element-wise product.
    pub fn hadamard(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.shape(), other.shape());
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a * b).collect();
        Matrix { rows: self.rows, cols: self.cols, data }
    }

    pub fn add(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.shape(), other.shape());
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a + b).collect();
        Matrix { rows: self.rows, cols: self.cols, data }
    }

    pub fn sub(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.shape(), other.shape());
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a - b).collect();
        Matrix { rows: self.rows, cols: self.cols, data }
    }

    pub fn scale(&self, s: f32) -> Matrix {
        let data = self.data.iter().map(|a| a * s).collect();
        Matrix { rows: self.rows, cols: self.cols, data }
    }

    pub fn axpy(&mut self, alpha: f32, other: &Matrix) {
        assert_eq!(self.shape(), other.shape());
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += alpha * b;
        }
    }

    /// Scale column `c` of every row by `s[c]`.
    pub fn scale_cols(&mut self, s: &[f32]) {
        assert_eq!(s.len(), self.cols);
        for r in 0..self.rows {
            let row = self.row_mut(r);
            for (x, f) in row.iter_mut().zip(s) {
                *x *= f;
            }
        }
    }

    /// Scale row `r` by `s[r]`.
    pub fn scale_rows(&mut self, s: &[f32]) {
        assert_eq!(s.len(), self.rows);
        for r in 0..self.rows {
            let f = s[r];
            for x in self.row_mut(r) {
                *x *= f;
            }
        }
    }

    pub fn frobenius_sq(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum()
    }

    /// Squared L2 norm of each column.
    pub fn col_sq_norms(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.cols];
        for r in 0..self.rows {
            let row = self.row(r);
            for c in 0..self.cols {
                out[c] += row[c] * row[c];
            }
        }
        out
    }

    /// Squared L2 norm of each row.
    pub fn row_sq_norms(&self) -> Vec<f32> {
        (0..self.rows)
            .map(|r| self.row(r).iter().map(|x| x * x).sum())
            .collect()
    }

    /// Copy the `br,bc`-th `bs × bs` block (paper notation `C^{(br,bc)}`,
    /// 0-indexed here).
    pub fn block(&self, br: usize, bc: usize, bs: usize) -> Matrix {
        let (r0, c0) = (br * bs, bc * bs);
        assert!(r0 + bs <= self.rows && c0 + bs <= self.cols);
        let mut out = Matrix::zeros(bs, bs);
        for r in 0..bs {
            out.row_mut(r).copy_from_slice(&self.row(r0 + r)[c0..c0 + bs]);
        }
        out
    }

    /// Write a `bs × bs` block back.
    pub fn set_block(&mut self, br: usize, bc: usize, bs: usize, blk: &Matrix) {
        assert_eq!(blk.shape(), (bs, bs));
        let (r0, c0) = (br * bs, bc * bs);
        for r in 0..bs {
            self.row_mut(r0 + r)[c0..c0 + bs].copy_from_slice(blk.row(r));
        }
    }

    pub fn max_abs_diff(&self, other: &Matrix) -> f32 {
        assert_eq!(self.shape(), other.shape());
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    pub fn iter(&self) -> std::slice::Iter<'_, f32> {
        self.data.iter()
    }

    /// True if all entries are finite (NaN/Inf guard used in tests and the
    /// coordinator's post-step validation).
    pub fn all_finite(&self) -> bool {
        self.data.iter().all(|x| x.is_finite())
    }
}

impl Index<(usize, usize)> for Matrix {
    type Output = f32;
    #[inline]
    fn index(&self, (r, c): (usize, usize)) -> &f32 {
        debug_assert!(r < self.rows && c < self.cols);
        &self.data[r * self.cols + c]
    }
}

impl IndexMut<(usize, usize)> for Matrix {
    #[inline]
    fn index_mut(&mut self, (r, c): (usize, usize)) -> &mut f32 {
        debug_assert!(r < self.rows && c < self.cols);
        &mut self.data[r * self.cols + c]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn indexing_row_major() {
        let m = Matrix::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(m[(0, 0)], 1.0);
        assert_eq!(m[(0, 2)], 3.0);
        assert_eq!(m[(1, 0)], 4.0);
        assert_eq!(m.row(1), &[4., 5., 6.]);
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Pcg64::seed_from_u64(0);
        let m = Matrix::randn(5, 7, &mut rng);
        assert_eq!(m.transpose().transpose(), m);
        assert_eq!(m.transpose()[(3, 2)], m[(2, 3)]);
    }

    #[test]
    fn elementwise_ops() {
        let a = Matrix::from_vec(2, 2, vec![1., 2., 3., 4.]);
        let b = Matrix::from_vec(2, 2, vec![5., 6., 7., 8.]);
        assert_eq!(a.add(&b).data, vec![6., 8., 10., 12.]);
        assert_eq!(b.sub(&a).data, vec![4., 4., 4., 4.]);
        assert_eq!(a.hadamard(&b).data, vec![5., 12., 21., 32.]);
        assert_eq!(a.scale(2.0).data, vec![2., 4., 6., 8.]);
        let mut c = a.clone();
        c.axpy(0.5, &b);
        assert_eq!(c.data, vec![3.5, 5., 6.5, 8.]);
    }

    #[test]
    fn norms() {
        let m = Matrix::from_vec(2, 2, vec![3., 0., 4., 0.]);
        assert_eq!(m.frobenius_sq(), 25.0);
        assert_eq!(m.col_sq_norms(), vec![25.0, 0.0]);
        assert_eq!(m.row_sq_norms(), vec![9.0, 16.0]);
    }

    #[test]
    fn block_get_set_roundtrip() {
        let mut rng = Pcg64::seed_from_u64(4);
        let mut m = Matrix::randn(8, 12, &mut rng);
        let blk = m.block(1, 2, 4);
        assert_eq!(blk[(0, 0)], m[(4, 8)]);
        let newblk = Matrix::ones(4, 4);
        m.set_block(1, 2, 4, &newblk);
        assert_eq!(m.block(1, 2, 4), newblk);
        // neighbours untouched
        assert_eq!(m.block(0, 0, 4), m.block(0, 0, 4));
    }

    #[test]
    fn scale_rows_cols() {
        let mut m = Matrix::ones(2, 3);
        m.scale_rows(&[2.0, 3.0]);
        assert_eq!(m.data, vec![2., 2., 2., 3., 3., 3.]);
        m.scale_cols(&[1.0, 0.5, 0.0]);
        assert_eq!(m.data, vec![2., 1., 0., 3., 1.5, 0.]);
    }

    #[test]
    fn finite_guard() {
        let mut m = Matrix::ones(2, 2);
        assert!(m.all_finite());
        m[(1, 1)] = f32::NAN;
        assert!(!m.all_finite());
    }
}
