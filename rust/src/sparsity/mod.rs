//! Sparsity patterns: binary masks, N:M semi-structured constraints,
//! unstructured top-k, and compressed 2:4 storage.

mod compressed;
pub use compressed::{q8_quantize, Compressed24, Compressed24Q8, DEFAULT_Q8_GROUP};

use crate::tensor::Matrix;

/// The sparsity pattern a pruner must satisfy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Pattern {
    /// N of every M consecutive columns kept, per row (paper's 2:4 is `NM(2,4)`).
    NM { n: usize, m: usize },
    /// Unstructured with the given kept fraction (e.g. 0.5 = 50% sparsity).
    Unstructured { keep_frac_x1000: usize },
}

impl Pattern {
    pub const TWO_FOUR: Pattern = Pattern::NM { n: 2, m: 4 };

    pub fn unstructured(keep_frac: f32) -> Pattern {
        Pattern::Unstructured { keep_frac_x1000: (keep_frac * 1000.0).round() as usize }
    }

    pub fn keep_frac(&self) -> f32 {
        match self {
            Pattern::NM { n, m } => *n as f32 / *m as f32,
            Pattern::Unstructured { keep_frac_x1000 } => *keep_frac_x1000 as f32 / 1000.0,
        }
    }

    /// Parse `"2:4"`, `"4:8"`, `"50%"`, or `"unstructured:0.5"`.
    pub fn parse(s: &str) -> Option<Pattern> {
        if let Some((n, m)) = s.split_once(':') {
            if let (Ok(n), Ok(m)) = (n.parse::<usize>(), m.parse::<usize>()) {
                if n <= m && m > 0 {
                    return Some(Pattern::NM { n, m });
                }
            }
            if n == "unstructured" {
                if let Ok(k) = m.parse::<f32>() {
                    return Some(Pattern::unstructured(k));
                }
            }
            return None;
        }
        if let Some(pct) = s.strip_suffix('%') {
            if let Ok(p) = pct.parse::<f32>() {
                return Some(Pattern::unstructured(1.0 - p / 100.0));
            }
        }
        None
    }

    pub fn label(&self) -> String {
        match self {
            Pattern::NM { n, m } => format!("{n}:{m}"),
            Pattern::Unstructured { keep_frac_x1000 } => {
                format!("{}%", 100 - keep_frac_x1000 / 10)
            }
        }
    }
}

/// Binary mask stored as bytes (0/1), same shape as the weight matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Mask {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<u8>,
}

impl Mask {
    pub fn ones(rows: usize, cols: usize) -> Mask {
        Mask { rows, cols, data: vec![1; rows * cols] }
    }

    pub fn zeros(rows: usize, cols: usize) -> Mask {
        Mask { rows, cols, data: vec![0; rows * cols] }
    }

    #[inline]
    pub fn get(&self, r: usize, c: usize) -> bool {
        self.data[r * self.cols + c] != 0
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: bool) {
        self.data[r * self.cols + c] = v as u8;
    }

    pub fn count_ones(&self) -> usize {
        self.data.iter().map(|&b| b as usize).sum()
    }

    pub fn density(&self) -> f32 {
        self.count_ones() as f32 / (self.rows * self.cols) as f32
    }

    /// Apply to a weight matrix: `W ⊙ M`.
    pub fn apply(&self, w: &Matrix) -> Matrix {
        assert_eq!((w.rows, w.cols), (self.rows, self.cols));
        let data = w.data.iter().zip(&self.data).map(|(x, &m)| if m != 0 { *x } else { 0.0 }).collect();
        Matrix { rows: w.rows, cols: w.cols, data }
    }

    /// Zero masked entries in place.
    pub fn apply_inplace(&self, w: &mut Matrix) {
        assert_eq!((w.rows, w.cols), (self.rows, self.cols));
        for (x, &m) in w.data.iter_mut().zip(&self.data) {
            if m == 0 {
                *x = 0.0;
            }
        }
    }

    /// As a 0.0/1.0 float matrix (for the PJRT artifacts, which take masks
    /// as f32 inputs).
    pub fn to_matrix(&self) -> Matrix {
        Matrix {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|&b| b as f32).collect(),
        }
    }

    pub fn from_matrix(m: &Matrix) -> Mask {
        Mask {
            rows: m.rows,
            cols: m.cols,
            data: m.data.iter().map(|&x| (x != 0.0) as u8).collect(),
        }
    }

    /// Check the paper's constraint `‖M_{i,[k]}‖₀ = n` for every row `i` and
    /// every group `k` of `m` consecutive columns.
    pub fn satisfies_nm(&self, n: usize, m: usize) -> bool {
        if self.cols % m != 0 {
            return false;
        }
        for r in 0..self.rows {
            let row = &self.data[r * self.cols..(r + 1) * self.cols];
            for g in row.chunks_exact(m) {
                if g.iter().map(|&b| b as usize).sum::<usize>() != n {
                    return false;
                }
            }
        }
        true
    }
}

/// Importance-score mask initialization: keep the top-`n` of every `m`
/// consecutive columns per row by `importance` (paper Eq. 3 with
/// `I_ij = W̄²_ij ‖X_j‖²` — the NoWag-P / Wanda-style criterion).
pub fn nm_mask_from_importance(importance: &Matrix, n: usize, m: usize) -> Mask {
    assert!(n <= m && m > 0, "invalid {n}:{m}");
    assert_eq!(importance.cols % m, 0, "cols {} not divisible by M={m}", importance.cols);
    let mut mask = Mask::zeros(importance.rows, importance.cols);
    let mut idx: Vec<usize> = Vec::with_capacity(m);
    for r in 0..importance.rows {
        let row = importance.row(r);
        for k in 0..importance.cols / m {
            let g = &row[k * m..(k + 1) * m];
            idx.clear();
            idx.extend(0..m);
            // sort descending by importance; stable so ties keep lower index
            idx.sort_by(|&a, &b| g[b].partial_cmp(&g[a]).unwrap_or(std::cmp::Ordering::Equal));
            for &i in idx.iter().take(n) {
                mask.set(r, k * m + i, true);
            }
        }
    }
    mask
}

/// Unstructured top-k mask: keep the `keep_frac` largest-importance entries
/// globally (matrix-wide threshold, matching NoWag-P's unstructured mode).
pub fn unstructured_mask_from_importance(importance: &Matrix, keep_frac: f32) -> Mask {
    let total = importance.rows * importance.cols;
    let keep = ((total as f64) * keep_frac as f64).round() as usize;
    let keep = keep.min(total);
    if keep == total {
        return Mask::ones(importance.rows, importance.cols);
    }
    let mut order: Vec<u32> = (0..total as u32).collect();
    order.sort_by(|&a, &b| {
        importance.data[b as usize]
            .partial_cmp(&importance.data[a as usize])
            .unwrap_or(std::cmp::Ordering::Equal)
    });
    let mut mask = Mask::zeros(importance.rows, importance.cols);
    for &i in order.iter().take(keep) {
        mask.data[i as usize] = 1;
    }
    mask
}

/// Build a mask for an arbitrary `Pattern`.
pub fn mask_from_importance(importance: &Matrix, pattern: Pattern) -> Mask {
    match pattern {
        Pattern::NM { n, m } => nm_mask_from_importance(importance, n, m),
        Pattern::Unstructured { .. } => {
            unstructured_mask_from_importance(importance, pattern.keep_frac())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn two_four_mask_valid_and_optimal() {
        let imp = Matrix::from_vec(1, 8, vec![0.1, 0.9, 0.5, 0.2, 1.0, 0.0, 0.3, 0.7]);
        let m = nm_mask_from_importance(&imp, 2, 4);
        assert!(m.satisfies_nm(2, 4));
        // group 0: keep cols 1 (0.9) and 2 (0.5)
        assert!(m.get(0, 1) && m.get(0, 2));
        // group 1: keep cols 4 (1.0) and 7 (0.7)
        assert!(m.get(0, 4) && m.get(0, 7));
        assert_eq!(m.count_ones(), 4);
    }

    #[test]
    fn nm_general_patterns() {
        let mut rng = Pcg64::seed_from_u64(0);
        let imp = Matrix::randn(16, 32, &mut rng).hadamard(&Matrix::randn(16, 32, &mut rng));
        for (n, m) in [(1, 4), (2, 4), (3, 4), (4, 8), (5, 8), (6, 8)] {
            let mask = nm_mask_from_importance(&imp, n, m);
            assert!(mask.satisfies_nm(n, m), "{n}:{m}");
            assert!((mask.density() - n as f32 / m as f32).abs() < 1e-6);
        }
    }

    #[test]
    fn unstructured_density() {
        let mut rng = Pcg64::seed_from_u64(1);
        let imp = Matrix::randn(20, 50, &mut rng);
        let m = unstructured_mask_from_importance(&imp, 0.5);
        assert_eq!(m.count_ones(), 500);
        // kept entries have importance >= dropped entries
        let kept_min = imp
            .data
            .iter()
            .zip(&m.data)
            .filter(|(_, &k)| k != 0)
            .map(|(&v, _)| v)
            .fold(f32::INFINITY, f32::min);
        let dropped_max = imp
            .data
            .iter()
            .zip(&m.data)
            .filter(|(_, &k)| k == 0)
            .map(|(&v, _)| v)
            .fold(f32::NEG_INFINITY, f32::max);
        assert!(kept_min >= dropped_max);
    }

    #[test]
    fn apply_zeroes_masked() {
        let w = Matrix::from_vec(1, 4, vec![1., 2., 3., 4.]);
        let mut m = Mask::zeros(1, 4);
        m.set(0, 1, true);
        m.set(0, 3, true);
        assert_eq!(m.apply(&w).data, vec![0., 2., 0., 4.]);
        let mut w2 = w.clone();
        m.apply_inplace(&mut w2);
        assert_eq!(w2.data, vec![0., 2., 0., 4.]);
    }

    #[test]
    fn satisfies_nm_detects_violations() {
        let mut m = Mask::zeros(1, 8);
        m.set(0, 0, true);
        m.set(0, 1, true);
        m.set(0, 4, true);
        m.set(0, 5, true);
        assert!(m.satisfies_nm(2, 4));
        m.set(0, 2, true); // 3 in group 0
        assert!(!m.satisfies_nm(2, 4));
    }

    #[test]
    fn mask_matrix_roundtrip() {
        let mut rng = Pcg64::seed_from_u64(2);
        let imp = Matrix::randn(8, 16, &mut rng);
        let m = nm_mask_from_importance(&imp, 2, 4);
        assert_eq!(Mask::from_matrix(&m.to_matrix()), m);
    }

    #[test]
    fn pattern_labels() {
        assert_eq!(Pattern::TWO_FOUR.label(), "2:4");
        assert_eq!(Pattern::unstructured(0.5).label(), "50%");
        assert_eq!(Pattern::NM { n: 4, m: 8 }.keep_frac(), 0.5);
    }

    #[test]
    fn pattern_parse() {
        assert_eq!(Pattern::parse("2:4"), Some(Pattern::TWO_FOUR));
        assert_eq!(Pattern::parse("5:8"), Some(Pattern::NM { n: 5, m: 8 }));
        assert_eq!(Pattern::parse("50%"), Some(Pattern::unstructured(0.5)));
        assert_eq!(Pattern::parse("unstructured:0.5"), Some(Pattern::unstructured(0.5)));
        assert_eq!(Pattern::parse("8:4"), None);
        assert_eq!(Pattern::parse("garbage"), None);
    }
}
