//! Compressed 2:4 storage and matvec.
//!
//! This is the CPU analog of NVIDIA's sparse-tensor-core format: for each
//! group of 4 consecutive columns we store the 2 surviving values plus a
//! 4-bit metadata nibble encoding which 2 of the 4 positions they occupy
//! (2 bits each). Memory: 2 f32 + 0.5 byte per group vs 4 f32 dense —
//! a 2× value reduction exactly as on Ampere.
//!
//! `matvec` walks the compressed layout directly, reading half the weight
//! bytes of the dense path. This is what reproduces the *shape* of the
//! paper's Table 4 (dense vs 2:4 vs ARMOR timings) on CPU.

use crate::sparsity::Mask;
use crate::tensor::Matrix;

/// A 2:4-compressed matrix: per row, `cols/4` groups of (2 values, 2+2 bits).
#[derive(Clone, Debug)]
pub struct Compressed24 {
    pub rows: usize,
    pub cols: usize,
    /// 2 surviving values per group, row-major: `values[r][2k], values[r][2k+1]`
    pub values: Vec<f32>,
    /// one metadata byte per group: low nibble = idx0 | idx1<<2
    pub meta: Vec<u8>,
}

impl Compressed24 {
    /// Compress `w ⊙ mask`, where `mask` must satisfy the 2:4 constraint.
    pub fn compress(w: &Matrix, mask: &Mask) -> crate::Result<Compressed24> {
        crate::ensure!(mask.satisfies_nm(2, 4), "mask is not 2:4");
        crate::ensure!((w.rows, w.cols) == (mask.rows, mask.cols), "shape mismatch");
        let groups_per_row = w.cols / 4;
        let mut values = Vec::with_capacity(w.rows * groups_per_row * 2);
        let mut meta = Vec::with_capacity(w.rows * groups_per_row);
        for r in 0..w.rows {
            let row = w.row(r);
            for k in 0..groups_per_row {
                let mut idxs = [0u8; 2];
                let mut n = 0;
                for i in 0..4 {
                    if mask.get(r, k * 4 + i) {
                        idxs[n] = i as u8;
                        values.push(row[k * 4 + i]);
                        n += 1;
                    }
                }
                debug_assert_eq!(n, 2);
                meta.push(idxs[0] | (idxs[1] << 2));
            }
        }
        Ok(Compressed24 { rows: w.rows, cols: w.cols, values, meta })
    }

    /// Decompress to a dense matrix (tests / verification).
    pub fn to_dense(&self) -> Matrix {
        let mut out = Matrix::zeros(self.rows, self.cols);
        let gpr = self.cols / 4;
        for r in 0..self.rows {
            for k in 0..gpr {
                let g = r * gpr + k;
                let m = self.meta[g];
                let (i0, i1) = ((m & 3) as usize, ((m >> 2) & 3) as usize);
                out[(r, k * 4 + i0)] = self.values[2 * g];
                out[(r, k * 4 + i1)] = self.values[2 * g + 1];
            }
        }
        out
    }

    /// Sparse matvec `y = Ŵ x` walking the compressed layout: per group only
    /// 2 multiply-adds and 8 weight bytes + 1 metadata byte are touched.
    pub fn matvec(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(x.len(), self.cols);
        let gpr = self.cols / 4;
        let mut y = vec![0.0f32; self.rows];
        for r in 0..self.rows {
            let vbase = r * gpr * 2;
            let mbase = r * gpr;
            let mut acc = 0.0f32;
            for k in 0..gpr {
                let m = self.meta[mbase + k];
                let xg = &x[k * 4..k * 4 + 4];
                acc += self.values[vbase + 2 * k] * xg[(m & 3) as usize]
                    + self.values[vbase + 2 * k + 1] * xg[((m >> 2) & 3) as usize];
            }
            y[r] = acc;
        }
        y
    }

    /// Decode the metadata nibbles once into absolute column indices
    /// (`2 * n_groups` entries, `[c0, c1]` per group). The decode is shared
    /// across every batch column in [`Compressed24::matmul`] instead of being
    /// re-derived per output element.
    fn decode_columns(&self) -> Vec<u32> {
        let gpr = self.cols / 4;
        let mut cols = Vec::with_capacity(self.meta.len() * 2);
        for (g, &m) in self.meta.iter().enumerate() {
            let base = ((g % gpr.max(1)) * 4) as u32;
            cols.push(base + (m & 3) as u32);
            cols.push(base + ((m >> 2) & 3) as u32);
        }
        cols
    }

    /// Batched matvec over the columns of `X` (`cols × batch`), producing
    /// `rows × batch`. Matches the paper's Table 4 "batched MatVec" workload.
    ///
    /// Blocked over the batch dimension: group metadata is decoded once
    /// (`decode_columns`), the output is split into row panels across the
    /// worker pool, and each panel walks the compressed weights once per
    /// batch block so the active `X[:, jb..jend]` slab stays cache-resident
    /// while the weights stream. Accumulation order per output element is
    /// identical to the reference path, so results are bit-exact with
    /// [`Compressed24::matmul_ref`].
    pub fn matmul(&self, x: &Matrix) -> Matrix {
        assert_eq!(x.rows, self.cols);
        let gpr = self.cols / 4;
        let b = x.cols;
        let mut out = Matrix::zeros(self.rows, b);
        if self.rows == 0 || b == 0 || gpr == 0 {
            return out;
        }
        let cols_dec = self.decode_columns();
        const JB: usize = 64;
        let n_threads = crate::util::threadpool::num_threads().max(1);
        let rows_per = self.rows.div_ceil(n_threads).max(1);
        crate::util::threadpool::parallel_chunks_mut(&mut out.data, rows_per * b, |start, chunk| {
            let r0 = start / b;
            let nrows = chunk.len() / b;
            for jb in (0..b).step_by(JB) {
                let jend = (jb + JB).min(b);
                for ri in 0..nrows {
                    let r = r0 + ri;
                    let vbase = r * gpr * 2;
                    let dbase = r * gpr * 2;
                    let orow = &mut chunk[ri * b + jb..ri * b + jend];
                    for k in 0..gpr {
                        let c0 = cols_dec[dbase + 2 * k] as usize;
                        let c1 = cols_dec[dbase + 2 * k + 1] as usize;
                        let v0 = self.values[vbase + 2 * k];
                        let v1 = self.values[vbase + 2 * k + 1];
                        let x0 = &x.row(c0)[jb..jend];
                        let x1 = &x.row(c1)[jb..jend];
                        for ((o, &a0), &a1) in orow.iter_mut().zip(x0).zip(x1) {
                            *o += v0 * a0 + v1 * a1;
                        }
                    }
                }
            }
        });
        out
    }

    /// Reference batched matvec: one independent [`Compressed24::matvec`] per
    /// batch column (the pre-optimization hot path, kept for verification and
    /// the `perf_hotpath` before/after comparison).
    pub fn matmul_ref(&self, x: &Matrix) -> Matrix {
        assert_eq!(x.rows, self.cols);
        let b = x.cols;
        let mut out = Matrix::zeros(self.rows, b);
        let mut col = vec![0.0f32; self.cols];
        for j in 0..b {
            for (i, c) in col.iter_mut().enumerate() {
                *c = x[(i, j)];
            }
            let y = self.matvec(&col);
            for (i, &yi) in y.iter().enumerate() {
                out[(i, j)] = yi;
            }
        }
        out
    }

    /// Stored bytes: 2 f32 values + 0.5 metadata byte per group
    /// (nibble-packable; we count the packed size for parity with hardware).
    pub fn storage_bytes(&self) -> usize {
        self.values.len() * 4 + self.meta.len().div_ceil(2)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparsity::nm_mask_from_importance;
    use crate::util::rng::Pcg64;

    fn random_compressed(rows: usize, cols: usize, seed: u64) -> (Matrix, Mask, Compressed24) {
        let mut rng = Pcg64::seed_from_u64(seed);
        let w = Matrix::randn(rows, cols, &mut rng);
        let imp = Matrix::randn(rows, cols, &mut rng).hadamard(&w);
        let mask = nm_mask_from_importance(&imp, 2, 4);
        let c = Compressed24::compress(&w, &mask).unwrap();
        (w, mask, c)
    }

    #[test]
    fn roundtrip_equals_masked_dense() {
        let (w, mask, c) = random_compressed(16, 32, 0);
        assert!(c.to_dense().max_abs_diff(&mask.apply(&w)) < 1e-7);
    }

    #[test]
    fn matvec_matches_dense() {
        let (w, mask, c) = random_compressed(8, 24, 1);
        let mut rng = Pcg64::seed_from_u64(9);
        let x: Vec<f32> = (0..24).map(|_| rng.next_gaussian()).collect();
        let want = crate::linalg::matvec(&mask.apply(&w), &x);
        let got = c.matvec(&x);
        for i in 0..8 {
            assert!((got[i] - want[i]).abs() < 1e-4);
        }
    }

    #[test]
    fn matmul_matches_dense() {
        let (w, mask, c) = random_compressed(8, 16, 2);
        let mut rng = Pcg64::seed_from_u64(10);
        let x = Matrix::randn(16, 5, &mut rng);
        let want = mask.apply(&w).matmul(&x);
        assert!(c.matmul(&x).max_abs_diff(&want) < 1e-4);
    }

    #[test]
    fn blocked_matmul_bit_exact_with_reference() {
        // shapes straddling the JB=64 batch block and the row-panel split
        for (rows, cols, batch, seed) in [(8, 16, 1, 5), (16, 32, 63, 6), (33, 24, 130, 7)] {
            let (_, _, c) = random_compressed(rows, cols, seed);
            let mut rng = Pcg64::seed_from_u64(seed + 100);
            let x = Matrix::randn(cols, batch, &mut rng);
            let blocked = c.matmul(&x);
            let reference = c.matmul_ref(&x);
            assert_eq!(blocked, reference, "{rows}x{cols} batch {batch}");
        }
    }

    #[test]
    fn matmul_empty_batch() {
        let (_, _, c) = random_compressed(8, 16, 11);
        let x = Matrix::zeros(16, 0);
        assert_eq!(c.matmul(&x).shape(), (8, 0));
    }

    #[test]
    fn storage_is_half_plus_meta() {
        let (_, _, c) = random_compressed(64, 128, 3);
        let dense_bytes = 64 * 128 * 4;
        assert!(c.storage_bytes() < dense_bytes * 6 / 10);
        assert!(c.storage_bytes() > dense_bytes * 4 / 10);
    }

    #[test]
    fn rejects_non_24_mask() {
        let w = Matrix::ones(2, 8);
        let mask = Mask::ones(2, 8);
        assert!(Compressed24::compress(&w, &mask).is_err());
    }
}
