//! Compressed 2:4 storage and matvec.
//!
//! This is the CPU analog of NVIDIA's sparse-tensor-core format: for each
//! group of 4 consecutive columns we store the 2 surviving values plus a
//! 4-bit metadata nibble encoding which 2 of the 4 positions they occupy
//! (2 bits each). Memory: 2 f32 + 0.5 byte per group vs 4 f32 dense —
//! a 2× value reduction exactly as on Ampere.
//!
//! `matvec` walks the compressed layout directly, reading half the weight
//! bytes of the dense path. This is what reproduces the *shape* of the
//! paper's Table 4 (dense vs 2:4 vs ARMOR timings) on CPU.

use crate::sparsity::Mask;
use crate::tensor::Matrix;

/// A 2:4-compressed matrix: per row, `cols/4` groups of (2 values, 2+2 bits).
#[derive(Clone, Debug)]
pub struct Compressed24 {
    pub rows: usize,
    pub cols: usize,
    /// 2 surviving values per group, row-major: `values[r][2k], values[r][2k+1]`
    pub values: Vec<f32>,
    /// one metadata byte per group: low nibble = idx0 | idx1<<2
    pub meta: Vec<u8>,
}

impl Compressed24 {
    /// Compress `w ⊙ mask`, where `mask` must satisfy the 2:4 constraint.
    pub fn compress(w: &Matrix, mask: &Mask) -> crate::Result<Compressed24> {
        anyhow::ensure!(mask.satisfies_nm(2, 4), "mask is not 2:4");
        anyhow::ensure!((w.rows, w.cols) == (mask.rows, mask.cols), "shape mismatch");
        let groups_per_row = w.cols / 4;
        let mut values = Vec::with_capacity(w.rows * groups_per_row * 2);
        let mut meta = Vec::with_capacity(w.rows * groups_per_row);
        for r in 0..w.rows {
            let row = w.row(r);
            for k in 0..groups_per_row {
                let mut idxs = [0u8; 2];
                let mut n = 0;
                for i in 0..4 {
                    if mask.get(r, k * 4 + i) {
                        idxs[n] = i as u8;
                        values.push(row[k * 4 + i]);
                        n += 1;
                    }
                }
                debug_assert_eq!(n, 2);
                meta.push(idxs[0] | (idxs[1] << 2));
            }
        }
        Ok(Compressed24 { rows: w.rows, cols: w.cols, values, meta })
    }

    /// Decompress to a dense matrix (tests / verification).
    pub fn to_dense(&self) -> Matrix {
        let mut out = Matrix::zeros(self.rows, self.cols);
        let gpr = self.cols / 4;
        for r in 0..self.rows {
            for k in 0..gpr {
                let g = r * gpr + k;
                let m = self.meta[g];
                let (i0, i1) = ((m & 3) as usize, ((m >> 2) & 3) as usize);
                out[(r, k * 4 + i0)] = self.values[2 * g];
                out[(r, k * 4 + i1)] = self.values[2 * g + 1];
            }
        }
        out
    }

    /// Sparse matvec `y = Ŵ x` walking the compressed layout: per group only
    /// 2 multiply-adds and 8 weight bytes + 1 metadata byte are touched.
    pub fn matvec(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(x.len(), self.cols);
        let gpr = self.cols / 4;
        let mut y = vec![0.0f32; self.rows];
        for r in 0..self.rows {
            let vbase = r * gpr * 2;
            let mbase = r * gpr;
            let mut acc = 0.0f32;
            for k in 0..gpr {
                let m = self.meta[mbase + k];
                let xg = &x[k * 4..k * 4 + 4];
                acc += self.values[vbase + 2 * k] * xg[(m & 3) as usize]
                    + self.values[vbase + 2 * k + 1] * xg[((m >> 2) & 3) as usize];
            }
            y[r] = acc;
        }
        y
    }

    /// Batched matvec over the columns of `X` (`cols × batch`), producing
    /// `rows × batch`. Matches the paper's Table 4 "batched MatVec" workload.
    pub fn matmul(&self, x: &Matrix) -> Matrix {
        assert_eq!(x.rows, self.cols);
        let gpr = self.cols / 4;
        let b = x.cols;
        let mut out = Matrix::zeros(self.rows, b);
        for r in 0..self.rows {
            let vbase = r * gpr * 2;
            let mbase = r * gpr;
            let orow = out.row_mut(r);
            for k in 0..gpr {
                let m = self.meta[mbase + k];
                let c0 = k * 4 + (m & 3) as usize;
                let c1 = k * 4 + ((m >> 2) & 3) as usize;
                let v0 = self.values[vbase + 2 * k];
                let v1 = self.values[vbase + 2 * k + 1];
                let x0 = x.row(c0);
                let x1 = x.row(c1);
                for j in 0..b {
                    orow[j] += v0 * x0[j] + v1 * x1[j];
                }
            }
        }
        out
    }

    /// Stored bytes: 2 f32 values + 0.5 metadata byte per group
    /// (nibble-packable; we count the packed size for parity with hardware).
    pub fn storage_bytes(&self) -> usize {
        self.values.len() * 4 + self.meta.len().div_ceil(2)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparsity::nm_mask_from_importance;
    use crate::util::rng::Pcg64;

    fn random_compressed(rows: usize, cols: usize, seed: u64) -> (Matrix, Mask, Compressed24) {
        let mut rng = Pcg64::seed_from_u64(seed);
        let w = Matrix::randn(rows, cols, &mut rng);
        let imp = Matrix::randn(rows, cols, &mut rng).hadamard(&w);
        let mask = nm_mask_from_importance(&imp, 2, 4);
        let c = Compressed24::compress(&w, &mask).unwrap();
        (w, mask, c)
    }

    #[test]
    fn roundtrip_equals_masked_dense() {
        let (w, mask, c) = random_compressed(16, 32, 0);
        assert!(c.to_dense().max_abs_diff(&mask.apply(&w)) < 1e-7);
    }

    #[test]
    fn matvec_matches_dense() {
        let (w, mask, c) = random_compressed(8, 24, 1);
        let mut rng = Pcg64::seed_from_u64(9);
        let x: Vec<f32> = (0..24).map(|_| rng.next_gaussian()).collect();
        let want = crate::linalg::matvec(&mask.apply(&w), &x);
        let got = c.matvec(&x);
        for i in 0..8 {
            assert!((got[i] - want[i]).abs() < 1e-4);
        }
    }

    #[test]
    fn matmul_matches_dense() {
        let (w, mask, c) = random_compressed(8, 16, 2);
        let mut rng = Pcg64::seed_from_u64(10);
        let x = Matrix::randn(16, 5, &mut rng);
        let want = mask.apply(&w).matmul(&x);
        assert!(c.matmul(&x).max_abs_diff(&want) < 1e-4);
    }

    #[test]
    fn storage_is_half_plus_meta() {
        let (_, _, c) = random_compressed(64, 128, 3);
        let dense_bytes = 64 * 128 * 4;
        assert!(c.storage_bytes() < dense_bytes * 6 / 10);
        assert!(c.storage_bytes() > dense_bytes * 4 / 10);
    }

    #[test]
    fn rejects_non_24_mask() {
        let w = Matrix::ones(2, 8);
        let mask = Mask::ones(2, 8);
        assert!(Compressed24::compress(&w, &mask).is_err());
    }
}
