//! Compressed 2:4 storage and matvec — f32 and int8 value planes.
//!
//! This is the CPU analog of NVIDIA's sparse-tensor-core format: for each
//! group of 4 consecutive columns we store the 2 surviving values plus a
//! 4-bit metadata nibble encoding which 2 of the 4 positions they occupy
//! (2 bits each). Memory: 2 f32 + 0.5 byte per group vs 4 f32 dense —
//! a 2× value reduction exactly as on Ampere.
//!
//! `matvec` walks the compressed layout directly, reading half the weight
//! bytes of the dense path. This is what reproduces the *shape* of the
//! paper's Table 4 (dense vs 2:4 vs ARMOR timings) on CPU.
//!
//! [`Compressed24Q8`] stacks a second compression axis on top: the packed
//! values are symmetric int8 with one f32 scale per [`DEFAULT_Q8_GROUP`]
//! consecutive packed values of a row (the 2:4 metadata is unchanged —
//! quantization touches the value plane only). The fused
//! [`Compressed24Q8::matmul_q8`] dequantizes on the fly inside the same
//! one-shot-metadata-decode + row-panel-threaded loop as the f32 blocked
//! path, so steady-state decode reads ~¼ of the f32-compressed weight
//! bytes. Quantization error per value is bounded by `scale/2 =
//! group_max/254` (symmetric round-to-nearest at 127 steps).

use crate::sparsity::Mask;
use crate::tensor::Matrix;

/// Default packed values per quantization scale group (must be even so the
/// two survivors of a 2:4 column group always share one scale).
pub const DEFAULT_Q8_GROUP: usize = 16;

/// Symmetric int8 quantization of one slice: returns the scale
/// (`max_abs / 127`; 0.0 for an all-zero slice) and writes the rounded,
/// clamped codes. Shared by the weight plane here and the KV page plane
/// (`serve::kv_pool`).
pub fn q8_quantize(src: &[f32], dst: &mut [i8]) -> f32 {
    debug_assert_eq!(src.len(), dst.len());
    let max_abs = src.iter().fold(0.0f32, |a, &x| a.max(x.abs()));
    if max_abs == 0.0 {
        dst.fill(0);
        return 0.0;
    }
    let scale = max_abs / 127.0;
    let inv = 127.0 / max_abs;
    for (d, &s) in dst.iter_mut().zip(src) {
        *d = (s * inv).round().clamp(-127.0, 127.0) as i8;
    }
    scale
}

/// A 2:4-compressed matrix: per row, `cols/4` groups of (2 values, 2+2 bits).
#[derive(Clone, Debug)]
pub struct Compressed24 {
    pub rows: usize,
    pub cols: usize,
    /// 2 surviving values per group, row-major: `values[r][2k], values[r][2k+1]`
    pub values: Vec<f32>,
    /// one metadata byte per group: low nibble = idx0 | idx1<<2
    pub meta: Vec<u8>,
}

impl Compressed24 {
    /// Compress `w ⊙ mask`, where `mask` must satisfy the 2:4 constraint.
    pub fn compress(w: &Matrix, mask: &Mask) -> crate::Result<Compressed24> {
        crate::ensure!(mask.satisfies_nm(2, 4), "mask is not 2:4");
        crate::ensure!((w.rows, w.cols) == (mask.rows, mask.cols), "shape mismatch");
        let groups_per_row = w.cols / 4;
        let mut values = Vec::with_capacity(w.rows * groups_per_row * 2);
        let mut meta = Vec::with_capacity(w.rows * groups_per_row);
        for r in 0..w.rows {
            let row = w.row(r);
            for k in 0..groups_per_row {
                let mut idxs = [0u8; 2];
                let mut n = 0;
                for i in 0..4 {
                    if mask.get(r, k * 4 + i) {
                        idxs[n] = i as u8;
                        values.push(row[k * 4 + i]);
                        n += 1;
                    }
                }
                debug_assert_eq!(n, 2);
                meta.push(idxs[0] | (idxs[1] << 2));
            }
        }
        Ok(Compressed24 { rows: w.rows, cols: w.cols, values, meta })
    }

    /// Decompress to a dense matrix (tests / verification).
    pub fn to_dense(&self) -> Matrix {
        let mut out = Matrix::zeros(self.rows, self.cols);
        let gpr = self.cols / 4;
        for r in 0..self.rows {
            for k in 0..gpr {
                let g = r * gpr + k;
                let m = self.meta[g];
                let (i0, i1) = ((m & 3) as usize, ((m >> 2) & 3) as usize);
                out[(r, k * 4 + i0)] = self.values[2 * g];
                out[(r, k * 4 + i1)] = self.values[2 * g + 1];
            }
        }
        out
    }

    /// Sparse matvec `y = Ŵ x` walking the compressed layout: per group only
    /// 2 multiply-adds and 8 weight bytes + 1 metadata byte are touched.
    pub fn matvec(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(x.len(), self.cols);
        let gpr = self.cols / 4;
        let mut y = vec![0.0f32; self.rows];
        for r in 0..self.rows {
            let vbase = r * gpr * 2;
            let mbase = r * gpr;
            let mut acc = 0.0f32;
            for k in 0..gpr {
                let m = self.meta[mbase + k];
                let xg = &x[k * 4..k * 4 + 4];
                acc += self.values[vbase + 2 * k] * xg[(m & 3) as usize]
                    + self.values[vbase + 2 * k + 1] * xg[((m >> 2) & 3) as usize];
            }
            y[r] = acc;
        }
        y
    }

    /// Decode the metadata nibbles once into absolute column indices
    /// (`2 * n_groups` entries, `[c0, c1]` per group). The decode is shared
    /// across every batch column in [`Compressed24::matmul`] instead of being
    /// re-derived per output element.
    fn decode_columns(&self) -> Vec<u32> {
        decode_meta_columns(&self.meta, self.cols / 4)
    }

    /// Batched matvec over the columns of `X` (`cols × batch`), producing
    /// `rows × batch`. Matches the paper's Table 4 "batched MatVec" workload.
    ///
    /// Blocked over the batch dimension: group metadata is decoded once
    /// (`decode_columns`), the output is split into row panels across the
    /// worker pool, and each panel walks the compressed weights once per
    /// batch block so the active `X[:, jb..jend]` slab stays cache-resident
    /// while the weights stream. Accumulation order per output element is
    /// identical to the reference path, so results are bit-exact with
    /// [`Compressed24::matmul_ref`].
    pub fn matmul(&self, x: &Matrix) -> Matrix {
        assert_eq!(x.rows, self.cols);
        let gpr = self.cols / 4;
        let b = x.cols;
        let mut out = Matrix::zeros(self.rows, b);
        if self.rows == 0 || b == 0 || gpr == 0 {
            return out;
        }
        let cols_dec = self.decode_columns();
        const JB: usize = 64;
        let n_threads = crate::util::threadpool::num_threads().max(1);
        let rows_per = self.rows.div_ceil(n_threads).max(1);
        crate::util::threadpool::parallel_chunks_mut(&mut out.data, rows_per * b, |start, chunk| {
            let r0 = start / b;
            let nrows = chunk.len() / b;
            for jb in (0..b).step_by(JB) {
                let jend = (jb + JB).min(b);
                for ri in 0..nrows {
                    let r = r0 + ri;
                    let vbase = r * gpr * 2;
                    let dbase = r * gpr * 2;
                    let orow = &mut chunk[ri * b + jb..ri * b + jend];
                    for k in 0..gpr {
                        let c0 = cols_dec[dbase + 2 * k] as usize;
                        let c1 = cols_dec[dbase + 2 * k + 1] as usize;
                        let v0 = self.values[vbase + 2 * k];
                        let v1 = self.values[vbase + 2 * k + 1];
                        let x0 = &x.row(c0)[jb..jend];
                        let x1 = &x.row(c1)[jb..jend];
                        for ((o, &a0), &a1) in orow.iter_mut().zip(x0).zip(x1) {
                            *o += v0 * a0 + v1 * a1;
                        }
                    }
                }
            }
        });
        out
    }

    /// Reference batched matvec: one independent [`Compressed24::matvec`] per
    /// batch column (the pre-optimization hot path, kept for verification and
    /// the `perf_hotpath` before/after comparison).
    pub fn matmul_ref(&self, x: &Matrix) -> Matrix {
        assert_eq!(x.rows, self.cols);
        let b = x.cols;
        let mut out = Matrix::zeros(self.rows, b);
        let mut col = vec![0.0f32; self.cols];
        for j in 0..b {
            for (i, c) in col.iter_mut().enumerate() {
                *c = x[(i, j)];
            }
            let y = self.matvec(&col);
            for (i, &yi) in y.iter().enumerate() {
                out[(i, j)] = yi;
            }
        }
        out
    }

    /// Stored bytes: 2 f32 values + 0.5 metadata byte per group
    /// (nibble-packable; we count the packed size for parity with hardware).
    pub fn storage_bytes(&self) -> usize {
        self.values.len() * 4 + self.meta.len().div_ceil(2)
    }

    /// Quantize the value plane to symmetric int8 with one f32 scale per
    /// `group` consecutive packed values of each row (the last group of a
    /// row may be ragged). The 2:4 metadata is shared unchanged. `group`
    /// must be even so the two survivors of a 4-column group never straddle
    /// a scale boundary.
    pub fn quantize(&self, group: usize) -> crate::Result<Compressed24Q8> {
        crate::ensure!(group >= 2 && group % 2 == 0, "q8 group must be even and >= 2, got {group}");
        let vals_per_row = (self.cols / 4) * 2;
        let groups_per_row = vals_per_row.div_ceil(group).max(1);
        let mut qvalues = vec![0i8; self.values.len()];
        let mut scales = Vec::with_capacity(self.rows * groups_per_row);
        for r in 0..self.rows {
            let base = r * vals_per_row;
            for g0 in (0..vals_per_row.max(1)).step_by(group) {
                let end = (g0 + group).min(vals_per_row);
                scales.push(q8_quantize(
                    &self.values[base + g0..base + end],
                    &mut qvalues[base + g0..base + end],
                ));
            }
        }
        Ok(Compressed24Q8 {
            rows: self.rows,
            cols: self.cols,
            group,
            qvalues,
            scales,
            meta: self.meta.clone(),
        })
    }
}

/// Metadata nibbles → absolute column indices (`[c0, c1]` per group), the
/// one-shot decode shared by the f32 and q8 blocked matmuls.
fn decode_meta_columns(meta: &[u8], gpr: usize) -> Vec<u32> {
    let mut cols = Vec::with_capacity(meta.len() * 2);
    for (g, &m) in meta.iter().enumerate() {
        let base = ((g % gpr.max(1)) * 4) as u32;
        cols.push(base + (m & 3) as u32);
        cols.push(base + ((m >> 2) & 3) as u32);
    }
    cols
}

/// A 2:4-compressed matrix with an int8 value plane: the same per-group
/// metadata as [`Compressed24`], values stored as symmetric int8 codes with
/// one f32 scale per `group` packed values per row. Memory per 4-column
/// group: 2 bytes of codes + 0.5 metadata byte + `8/group` scale bytes —
/// ~¼ of the f32-compressed layout at the default group of 16.
#[derive(Clone, Debug)]
pub struct Compressed24Q8 {
    pub rows: usize,
    pub cols: usize,
    /// packed values per scale group (even; last group of a row ragged)
    pub group: usize,
    /// int8 codes, same layout as [`Compressed24::values`]
    pub qvalues: Vec<i8>,
    /// row-major scales: `rows × ceil(vals_per_row / group)`
    pub scales: Vec<f32>,
    /// one metadata byte per 4-column group (same encoding as f32)
    pub meta: Vec<u8>,
}

impl Compressed24Q8 {
    /// Compress and quantize in one step (`compress` → [`Compressed24::quantize`]).
    pub fn compress(w: &Matrix, mask: &Mask, group: usize) -> crate::Result<Compressed24Q8> {
        Compressed24::compress(w, mask)?.quantize(group)
    }

    #[inline]
    fn vals_per_row(&self) -> usize {
        (self.cols / 4) * 2
    }

    #[inline]
    fn scale_groups_per_row(&self) -> usize {
        self.vals_per_row().div_ceil(self.group).max(1)
    }

    /// Dequantize one packed value.
    #[inline]
    fn deq(&self, r: usize, i: usize) -> f32 {
        let sbase = r * self.scale_groups_per_row();
        self.qvalues[r * self.vals_per_row() + i] as f32 * self.scales[sbase + i / self.group]
    }

    /// Decompress + dequantize to a dense matrix (tests / verification).
    pub fn to_dense(&self) -> Matrix {
        let mut out = Matrix::zeros(self.rows, self.cols);
        let gpr = self.cols / 4;
        for r in 0..self.rows {
            for k in 0..gpr {
                let m = self.meta[r * gpr + k];
                let (i0, i1) = ((m & 3) as usize, ((m >> 2) & 3) as usize);
                out[(r, k * 4 + i0)] = self.deq(r, 2 * k);
                out[(r, k * 4 + i1)] = self.deq(r, 2 * k + 1);
            }
        }
        out
    }

    /// Scalar sparse matvec with on-the-fly dequantization — the q8 analog
    /// of [`Compressed24::matvec`] and the accumulation-order reference for
    /// the blocked path.
    pub fn matvec_q8(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(x.len(), self.cols);
        let gpr = self.cols / 4;
        let sgpr = self.scale_groups_per_row();
        let mut y = vec![0.0f32; self.rows];
        for r in 0..self.rows {
            let vbase = r * gpr * 2;
            let mbase = r * gpr;
            let sbase = r * sgpr;
            let mut acc = 0.0f32;
            for k in 0..gpr {
                let m = self.meta[mbase + k];
                let xg = &x[k * 4..k * 4 + 4];
                // the value pair never straddles a scale group (group is even)
                let s = self.scales[sbase + (2 * k) / self.group];
                let w0 = self.qvalues[vbase + 2 * k] as f32 * s;
                let w1 = self.qvalues[vbase + 2 * k + 1] as f32 * s;
                acc += w0 * xg[(m & 3) as usize] + w1 * xg[((m >> 2) & 3) as usize];
            }
            y[r] = acc;
        }
        y
    }

    /// Fused dequant-accumulate batched matvec (`cols × batch` → `rows ×
    /// batch`): the same one-shot metadata decode, JB batch blocking, and
    /// row-panel threading as [`Compressed24::matmul`], with the int8 codes
    /// dequantized in registers as they stream — the f32 weights are never
    /// materialized. Accumulation order per output element is identical to
    /// [`Compressed24Q8::matmul_q8_ref`], so the two are bit-exact.
    pub fn matmul_q8(&self, x: &Matrix) -> Matrix {
        assert_eq!(x.rows, self.cols);
        let gpr = self.cols / 4;
        let sgpr = self.scale_groups_per_row();
        let b = x.cols;
        let mut out = Matrix::zeros(self.rows, b);
        if self.rows == 0 || b == 0 || gpr == 0 {
            return out;
        }
        let cols_dec = decode_meta_columns(&self.meta, gpr);
        const JB: usize = 64;
        let n_threads = crate::util::threadpool::num_threads().max(1);
        let rows_per = self.rows.div_ceil(n_threads).max(1);
        crate::util::threadpool::parallel_chunks_mut(&mut out.data, rows_per * b, |start, chunk| {
            let r0 = start / b;
            let nrows = chunk.len() / b;
            for jb in (0..b).step_by(JB) {
                let jend = (jb + JB).min(b);
                for ri in 0..nrows {
                    let r = r0 + ri;
                    let vbase = r * gpr * 2;
                    let dbase = r * gpr * 2;
                    let sbase = r * sgpr;
                    let orow = &mut chunk[ri * b + jb..ri * b + jend];
                    for k in 0..gpr {
                        let c0 = cols_dec[dbase + 2 * k] as usize;
                        let c1 = cols_dec[dbase + 2 * k + 1] as usize;
                        let s = self.scales[sbase + (2 * k) / self.group];
                        let v0 = self.qvalues[vbase + 2 * k] as f32 * s;
                        let v1 = self.qvalues[vbase + 2 * k + 1] as f32 * s;
                        let x0 = &x.row(c0)[jb..jend];
                        let x1 = &x.row(c1)[jb..jend];
                        for ((o, &a0), &a1) in orow.iter_mut().zip(x0).zip(x1) {
                            *o += v0 * a0 + v1 * a1;
                        }
                    }
                }
            }
        });
        out
    }

    /// Reference batched matvec: one [`Compressed24Q8::matvec_q8`] per batch
    /// column — the scalar oracle the blocked path is bit-exact against.
    pub fn matmul_q8_ref(&self, x: &Matrix) -> Matrix {
        assert_eq!(x.rows, self.cols);
        let b = x.cols;
        let mut out = Matrix::zeros(self.rows, b);
        let mut col = vec![0.0f32; self.cols];
        for j in 0..b {
            for (i, c) in col.iter_mut().enumerate() {
                *c = x[(i, j)];
            }
            let y = self.matvec_q8(&col);
            for (i, &yi) in y.iter().enumerate() {
                out[(i, j)] = yi;
            }
        }
        out
    }

    /// Stored bytes: 1 int8 code per kept value + 0.5 metadata byte per
    /// 4-column group + 4 bytes per scale group.
    pub fn storage_bytes(&self) -> usize {
        self.qvalues.len() + self.meta.len().div_ceil(2) + self.scales.len() * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparsity::nm_mask_from_importance;
    use crate::util::rng::Pcg64;

    fn random_compressed(rows: usize, cols: usize, seed: u64) -> (Matrix, Mask, Compressed24) {
        let mut rng = Pcg64::seed_from_u64(seed);
        let w = Matrix::randn(rows, cols, &mut rng);
        let imp = Matrix::randn(rows, cols, &mut rng).hadamard(&w);
        let mask = nm_mask_from_importance(&imp, 2, 4);
        let c = Compressed24::compress(&w, &mask).unwrap();
        (w, mask, c)
    }

    #[test]
    fn roundtrip_equals_masked_dense() {
        let (w, mask, c) = random_compressed(16, 32, 0);
        assert!(c.to_dense().max_abs_diff(&mask.apply(&w)) < 1e-7);
    }

    #[test]
    fn matvec_matches_dense() {
        let (w, mask, c) = random_compressed(8, 24, 1);
        let mut rng = Pcg64::seed_from_u64(9);
        let x: Vec<f32> = (0..24).map(|_| rng.next_gaussian()).collect();
        let want = crate::linalg::matvec(&mask.apply(&w), &x);
        let got = c.matvec(&x);
        for i in 0..8 {
            assert!((got[i] - want[i]).abs() < 1e-4);
        }
    }

    #[test]
    fn matmul_matches_dense() {
        let (w, mask, c) = random_compressed(8, 16, 2);
        let mut rng = Pcg64::seed_from_u64(10);
        let x = Matrix::randn(16, 5, &mut rng);
        let want = mask.apply(&w).matmul(&x);
        assert!(c.matmul(&x).max_abs_diff(&want) < 1e-4);
    }

    #[test]
    fn blocked_matmul_bit_exact_with_reference() {
        // shapes straddling the JB=64 batch block and the row-panel split
        for (rows, cols, batch, seed) in [(8, 16, 1, 5), (16, 32, 63, 6), (33, 24, 130, 7)] {
            let (_, _, c) = random_compressed(rows, cols, seed);
            let mut rng = Pcg64::seed_from_u64(seed + 100);
            let x = Matrix::randn(cols, batch, &mut rng);
            let blocked = c.matmul(&x);
            let reference = c.matmul_ref(&x);
            assert_eq!(blocked, reference, "{rows}x{cols} batch {batch}");
        }
    }

    #[test]
    fn matmul_empty_batch() {
        let (_, _, c) = random_compressed(8, 16, 11);
        let x = Matrix::zeros(16, 0);
        assert_eq!(c.matmul(&x).shape(), (8, 0));
    }

    #[test]
    fn storage_is_half_plus_meta() {
        let (_, _, c) = random_compressed(64, 128, 3);
        let dense_bytes = 64 * 128 * 4;
        assert!(c.storage_bytes() < dense_bytes * 6 / 10);
        assert!(c.storage_bytes() > dense_bytes * 4 / 10);
    }

    #[test]
    fn rejects_non_24_mask() {
        let w = Matrix::ones(2, 8);
        let mask = Mask::ones(2, 8);
        assert!(Compressed24::compress(&w, &mask).is_err());
    }

    // ---- int8 value plane ----

    #[test]
    fn q8_quantize_slice_bounds_and_zero_guard() {
        let src = [0.5f32, -1.0, 0.25, 0.0];
        let mut dst = [0i8; 4];
        let scale = q8_quantize(&src, &mut dst);
        assert_eq!(scale, 1.0 / 127.0);
        assert_eq!(dst[1], -127);
        for (i, &q) in dst.iter().enumerate() {
            assert!((q as f32 * scale - src[i]).abs() <= scale / 2.0 + 1e-7, "elem {i}");
        }
        let mut dst = [7i8; 3];
        assert_eq!(q8_quantize(&[0.0; 3], &mut dst), 0.0);
        assert_eq!(dst, [0, 0, 0]);
    }

    #[test]
    fn q8_roundtrip_error_bounded_by_group_scale() {
        let (w, mask, c) = random_compressed(16, 64, 21);
        for group in [2usize, 8, 16, 32] {
            let q = c.quantize(group).unwrap();
            assert_eq!(q.meta, c.meta, "metadata must be untouched by quantization");
            let dense = mask.apply(&w);
            let deq = q.to_dense();
            // per-element error <= scale/2, scale = group_max/127 <= w_max/127
            let wmax = w.data.iter().fold(0.0f32, |a, &x| a.max(x.abs()));
            assert!(
                deq.max_abs_diff(&dense) <= wmax / 254.0 + 1e-6,
                "group {group}: err {}",
                deq.max_abs_diff(&dense)
            );
        }
    }

    #[test]
    fn q8_rejects_odd_or_tiny_group() {
        let (_, _, c) = random_compressed(4, 16, 22);
        assert!(c.quantize(3).is_err(), "odd group straddles 2:4 value pairs");
        assert!(c.quantize(0).is_err());
        assert!(c.quantize(2).is_ok());
    }

    #[test]
    fn q8_matvec_matches_dequantized_dense() {
        let (_, _, c) = random_compressed(8, 24, 23);
        let q = c.quantize(4).unwrap();
        let mut rng = Pcg64::seed_from_u64(24);
        let x: Vec<f32> = (0..24).map(|_| rng.next_gaussian()).collect();
        let want = crate::linalg::matvec(&q.to_dense(), &x);
        let got = q.matvec_q8(&x);
        for i in 0..8 {
            assert!((got[i] - want[i]).abs() < 1e-4, "row {i}: {} vs {}", got[i], want[i]);
        }
    }

    #[test]
    fn q8_blocked_matmul_bit_exact_with_reference() {
        // shapes straddling the JB=64 batch block, the row-panel split, and
        // ragged scale groups (24 cols -> 12 packed values, group 16 ragged)
        for (rows, cols, batch, group, seed) in
            [(8, 16, 1, 2, 30), (16, 32, 63, 16, 31), (33, 24, 130, 16, 32), (5, 64, 70, 8, 33)]
        {
            let (_, _, c) = random_compressed(rows, cols, seed);
            let q = c.quantize(group).unwrap();
            let mut rng = Pcg64::seed_from_u64(seed + 100);
            let x = Matrix::randn(cols, batch, &mut rng);
            let blocked = q.matmul_q8(&x);
            let reference = q.matmul_q8_ref(&x);
            assert_eq!(blocked, reference, "{rows}x{cols} batch {batch} group {group}");
        }
    }

    #[test]
    fn q8_matmul_close_to_f32_matmul() {
        let (_, _, c) = random_compressed(16, 32, 40);
        let q = c.quantize(DEFAULT_Q8_GROUP).unwrap();
        let mut rng = Pcg64::seed_from_u64(41);
        let x = Matrix::randn(32, 7, &mut rng);
        let f32_out = c.matmul(&x);
        let q8_out = q.matmul_q8(&x);
        // bound: per-weight error <= wmax/254, each output sums 16 group
        // contributions of 2 values -> err <= wmax/254 * sum|x| over the row
        let wmax = c.values.iter().fold(0.0f32, |a, &x| a.max(x.abs()));
        for j in 0..7 {
            let l1: f32 = (0..32).map(|i| x[(i, j)].abs()).sum();
            let tol = wmax / 254.0 * l1 * 1.5 + 1e-5;
            for i in 0..16 {
                let d = (q8_out[(i, j)] - f32_out[(i, j)]).abs();
                assert!(d <= tol, "({i},{j}): diff {d} > tol {tol}");
            }
        }
    }

    #[test]
    fn q8_storage_is_quarter_of_f32_compressed() {
        let (_, _, c) = random_compressed(64, 128, 42);
        let q = c.quantize(DEFAULT_Q8_GROUP).unwrap();
        // codes: values/4 of the f32 bytes; meta identical; scales amortized
        assert!(q.storage_bytes() * 10 < c.storage_bytes() * 4, "q8 {} vs f32 {}", q.storage_bytes(), c.storage_bytes());
        // 1B code + 0.5B meta per 4-col group + amortized scales ≈ 19% of dense
        let dense_bytes = 64 * 128 * 4;
        assert!(q.storage_bytes() < dense_bytes / 5, "q8 {} vs dense {}", q.storage_bytes(), dense_bytes);
    }
}
