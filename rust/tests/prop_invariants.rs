//! Property-based tests over the coordinator/optimizer invariants, via the
//! in-repo `prop` mini-framework (proptest is unavailable offline).
//! Override case counts with `ARMOR_PROP_CASES`, reproduce failures with
//! `ARMOR_PROP_SEED`.

use armor::armor::{
    initialize, prune_matrix, sparse_core_step, ArmorConfig, ContinuousOpt, SelectionHeuristic,
};
use armor::baselines::Method;
use armor::coordinator::{calibrate, prune_model, PruneJob};
use armor::model::{attend_batch_scalar, AttnKernel, CompiledModel, GptConfig, GptModel, NoCapture};
use armor::prop::{forall, num_cases, Gen};
use armor::serve::KvCache;
use armor::sparsity::{mask_from_importance, Pattern};
use armor::tensor::Matrix;
use armor::util::rng::Pcg64;

struct Layer {
    w: Matrix,
    d: Vec<f32>,
    db: usize,
    seed: u64,
}

fn gen_layer(rng: &mut Pcg64) -> Layer {
    let w = Gen::matrix(rng, &[8, 16, 24, 32], 8);
    let d = Gen::act_norms(rng, w.cols);
    let db = Gen::block_size(rng, w.rows, w.cols);
    Layer { w, d, db, seed: rng.next_u64() }
}

/// Theorem 3.1 (sequential GD): the loss trajectory never increases, for
/// arbitrary layer shapes, block sizes, and degenerate activation stats.
#[test]
fn prop_monotone_descent_sequential_gd() {
    forall("monotone descent", num_cases(12), gen_layer, |l| {
        // d_block must be divisible by M=4 for the sparse step
        let db = if l.db % 4 == 0 { l.db } else { 8.min(l.w.rows).min(l.w.cols) };
        if l.w.rows % db != 0 || l.w.cols % db != 0 || db % 4 != 0 {
            return Ok(()); // shape not expressible; vacuously true
        }
        let cfg = ArmorConfig {
            d_block: db,
            n_iters: 8,
            optimizer: ContinuousOpt::SequentialGd,
            record_every: 1,
            seed: l.seed,
            ..Default::default()
        };
        let res = prune_matrix(&l.w, &l.d, &cfg, &mut Pcg64::seed_from_u64(l.seed));
        let mut prev = f64::INFINITY;
        for rec in &res.history {
            if rec.loss > prev + 1e-6 * prev.max(1.0) {
                return Err(format!("loss rose at iter {}: {prev} -> {}", rec.iter, rec.loss));
            }
            prev = rec.loss;
        }
        if !res.final_loss.is_finite() {
            return Err("non-finite final loss".into());
        }
        Ok(())
    });
}

/// The 2:4 mask constraint survives any number of sparse-core steps under
/// every selection heuristic.
#[test]
fn prop_mask_constraint_preserved() {
    forall("mask constraint", num_cases(10), gen_layer, |l| {
        let db = 8;
        if l.w.rows % db != 0 || l.w.cols % db != 0 {
            return Ok(());
        }
        let (mut fact, problem, _) = initialize(&l.w, &l.d, db, Pattern::TWO_FOUR);
        let mut rng = Pcg64::seed_from_u64(l.seed);
        for h in [
            SelectionHeuristic::Random,
            SelectionHeuristic::L1Greedy,
            SelectionHeuristic::L2Random,
            SelectionHeuristic::L1Random,
        ] {
            sparse_core_step(&mut fact, &problem, 2, 4, h, &mut rng);
            if !fact.mask.satisfies_nm(2, 4) {
                return Err(format!("{h:?} broke the 2:4 constraint"));
            }
            if !fact.w_prime.all_finite() {
                return Err(format!("{h:?} produced non-finite weights"));
            }
        }
        Ok(())
    });
}

/// ARMOR's final proxy loss never exceeds its NoWag-P initialization
/// (the Theorem 3.1 floor), for the practical Adam optimizer too.
#[test]
fn prop_never_worse_than_nowag() {
    forall("floor guarantee", num_cases(10), gen_layer, |l| {
        let db = 8;
        if l.w.rows % db != 0 || l.w.cols % db != 0 {
            return Ok(());
        }
        let cfg = ArmorConfig {
            d_block: db,
            n_iters: 15,
            optimizer: ContinuousOpt::Adam { lr: 1e-3 },
            seed: l.seed,
            ..Default::default()
        };
        let res = prune_matrix(&l.w, &l.d, &cfg, &mut Pcg64::seed_from_u64(l.seed));
        if res.final_loss > res.initial_loss * (1.0 + 1e-6) {
            return Err(format!("{} -> {}", res.initial_loss, res.final_loss));
        }
        Ok(())
    });
}

/// Mask construction density is exact for every N:M pattern on arbitrary
/// importance matrices (including ties and zeros).
#[test]
fn prop_nm_mask_density_exact() {
    forall("mask density", num_cases(20), gen_layer, |l| {
        for (n, m) in [(1usize, 4usize), (2, 4), (3, 4), (4, 8), (5, 8), (6, 8)] {
            if l.w.cols % m != 0 {
                continue;
            }
            let imp = l.w.hadamard(&l.w);
            let mask = mask_from_importance(&imp, Pattern::NM { n, m });
            if !mask.satisfies_nm(n, m) {
                return Err(format!("{n}:{m} violated"));
            }
            let want = l.w.rows * l.w.cols * n / m;
            if mask.count_ones() != want {
                return Err(format!("{n}:{m}: {} ones, want {want}", mask.count_ones()));
            }
        }
        Ok(())
    });
}

/// Round-trip: compressed 2:4 storage reproduces the masked dense matrix
/// exactly, and its matvec agrees with the dense one.
#[test]
fn prop_compressed24_roundtrip() {
    forall("compressed 2:4", num_cases(15), gen_layer, |l| {
        if l.w.cols % 4 != 0 {
            return Ok(());
        }
        let imp = l.w.hadamard(&l.w);
        let mask = mask_from_importance(&imp, Pattern::TWO_FOUR);
        let c = armor::sparsity::Compressed24::compress(&l.w, &mask)
            .map_err(|e| e.to_string())?;
        let dense = mask.apply(&l.w);
        if c.to_dense().max_abs_diff(&dense) > 1e-6 {
            return Err("decompress mismatch".into());
        }
        let mut rng = Pcg64::seed_from_u64(l.seed);
        let x: Vec<f32> = (0..l.w.cols).map(|_| rng.next_gaussian()).collect();
        let got = c.matvec(&x);
        let want = armor::linalg::matvec(&dense, &x);
        for i in 0..got.len() {
            if (got[i] - want[i]).abs() > 1e-3 * (1.0 + want[i].abs()) {
                return Err(format!("matvec row {i}: {} vs {}", got[i], want[i]));
            }
        }
        Ok(())
    });
}

struct ServeCase {
    model: GptModel,
    method: Method,
    tokens: Vec<u16>,
    seed: u64,
}

fn gen_serve_case(rng: &mut Pcg64) -> ServeCase {
    let d_model = [16usize, 32][rng.next_below(2) as usize];
    let cfg = GptConfig {
        d_model,
        n_layers: 1 + rng.next_below(2) as usize,
        n_heads: 2,
        d_ff: d_model * 2,
        max_seq: 24,
        ..GptConfig::tiny()
    };
    let model = GptModel::random_init(&cfg, rng);
    let method = match rng.next_below(3) {
        0 => Method::Wanda,
        1 => Method::NoWagP,
        _ => Method::Armor(ArmorConfig { d_block: 8, n_iters: 4, ..Default::default() }),
    };
    let tokens = (0..6 + rng.next_below(6) as usize)
        .map(|_| rng.next_below(256) as u16)
        .collect();
    ServeCase { model, method, tokens, seed: rng.next_u64() }
}

/// Compile→execute parity: lowering a pruned model to its deployment form
/// preserves the forward outputs of the uncompiled pruned model, and
/// KV-cached decoding reproduces the full forward logits — for 2:4
/// compressed cores and native ARMOR `A·S·B` execution alike.
#[test]
fn prop_compile_execute_preserves_outputs() {
    forall("compile/execute parity", num_cases(6), gen_serve_case, |case| {
        let calib = vec![case.tokens.clone()];
        let stats = calibrate(&case.model, &calib, false);
        let job = PruneJob {
            method: case.method.clone(),
            pattern: Pattern::TWO_FOUR,
            seed: case.seed,
            use_xla: false,
        };
        let (pruned, report) = prune_model(&case.model, &stats, &job, None);
        let compiled = CompiledModel::compile(&pruned, Some(&report))
            .map_err(|e| e.to_string())?;
        if matches!(case.method, Method::Armor(_)) {
            if !compiled.exec_summary().contains_key("armor") {
                return Err(format!(
                    "ARMOR factorizations lost in compilation: {:?}",
                    compiled.exec_summary()
                ));
            }
        } else if !compiled.exec_summary().contains_key("2:4") {
            return Err(format!("2:4 cores not detected: {:?}", compiled.exec_summary()));
        }

        // compiled forward vs the uncompiled pruned model
        let want = pruned.forward(&case.tokens, &mut NoCapture);
        let full = compiled.forward(&case.tokens);
        let scale = want.data.iter().fold(1.0f32, |a, &x| a.max(x.abs()));
        if full.max_abs_diff(&want) > 2e-3 * scale {
            return Err(format!(
                "compiled forward drifted: {} (scale {scale})",
                full.max_abs_diff(&want)
            ));
        }

        // KV-cached decode vs the compiled full forward
        let mut cache = KvCache::new(&compiled.cfg);
        for (i, &tok) in case.tokens.iter().enumerate() {
            let logits = compiled.decode_step(&mut cache, tok);
            for c in 0..full.cols {
                if (logits[c] - full[(i, c)]).abs() > 1e-4 {
                    return Err(format!(
                        "decode_step pos {i} logit {c}: {} vs {}",
                        logits[c],
                        full[(i, c)]
                    ));
                }
            }
        }
        Ok(())
    });
}

struct AttnCase {
    n_heads: usize,
    head_dim: usize,
    n_layers: usize,
    max_seq: usize,
    /// cached positions per sequence — ragged by construction
    lens: Vec<usize>,
    seed: u64,
}

fn gen_attn_case(rng: &mut Pcg64) -> AttnCase {
    let n_heads = [1usize, 2, 3, 4][rng.next_below(4) as usize];
    let head_dim = [4usize, 8, 10, 16][rng.next_below(4) as usize];
    let max_seq = 32;
    let bsz = 1 + rng.next_below(8) as usize;
    let lens = (0..bsz).map(|_| 1 + rng.next_below(max_seq as u32) as usize).collect();
    AttnCase {
        n_heads,
        head_dim,
        n_layers: 1 + rng.next_below(2) as usize,
        max_seq,
        lens,
        seed: rng.next_u64(),
    }
}

/// The blocked batch-shared attention kernel matches the scalar
/// per-sequence reference bit-close on ragged batches — mixed sequence
/// lengths, batch sizes, head counts, and head dims (including dims that
/// straddle the kernel's 4-lane unroll and 4-row accumulation tiles).
#[test]
fn prop_blocked_attention_matches_scalar() {
    forall("attention parity", num_cases(10), gen_attn_case, |case| {
        let d_model = case.n_heads * case.head_dim;
        let cfg = GptConfig {
            d_model,
            n_layers: case.n_layers,
            n_heads: case.n_heads,
            d_ff: 2 * d_model,
            max_seq: case.max_seq,
            ..GptConfig::tiny()
        };
        let mut rng = Pcg64::seed_from_u64(case.seed);
        let caches: Vec<KvCache> = case
            .lens
            .iter()
            .map(|&n| {
                let mut c = KvCache::new(&cfg);
                for _ in 0..n {
                    let k: Vec<f32> = (0..d_model).map(|_| rng.next_gaussian()).collect();
                    let v: Vec<f32> = (0..d_model).map(|_| rng.next_gaussian()).collect();
                    for l in 0..cfg.n_layers {
                        c.append(l, &k, &v);
                    }
                    c.advance(1);
                }
                c
            })
            .collect();
        let shared: Vec<&KvCache> = caches.iter().collect();
        let q = Matrix::randn(case.lens.len(), d_model, &mut rng);
        let kern = AttnKernel::new(cfg.n_heads, cfg.head_dim());
        for layer in 0..cfg.n_layers {
            let blocked = kern.attend_batch(&shared, layer, &q, &case.lens);
            let scalar = attend_batch_scalar(&shared, layer, &q, &case.lens, cfg.n_heads);
            for i in 0..case.lens.len() {
                for c in 0..d_model {
                    let (b, s) = (blocked[(i, c)], scalar[(i, c)]);
                    if (b - s).abs() > 1e-5 * (1.0 + s.abs()) {
                        return Err(format!(
                            "layer {layer} seq {i} (len {}) col {c}: blocked {b} vs scalar {s}",
                            case.lens[i]
                        ));
                    }
                }
            }
        }
        Ok(())
    });
}

struct PagedCase {
    n_heads: usize,
    head_dim: usize,
    page_positions: usize,
    /// committed positions of the shared base cache
    base_len: usize,
    /// per sequence: (fork split point <= base_len, divergent suffix rows)
    forks: Vec<(usize, usize)>,
    seed: u64,
}

fn gen_paged_case(rng: &mut Pcg64) -> PagedCase {
    let max_seq = 32;
    let base_len = 1 + rng.next_below(20) as usize;
    let bsz = 1 + rng.next_below(5) as usize;
    let forks = (0..bsz)
        .map(|_| {
            let split = rng.next_below(base_len as u32 + 1) as usize;
            let suffix_max = (max_seq - split) as u32;
            let mut suffix = rng.next_below(suffix_max.min(9)) as usize;
            if split + suffix == 0 {
                suffix = 1; // a sequence must attend over >= 1 position
            }
            (split, suffix)
        })
        .collect();
    PagedCase {
        n_heads: [1usize, 2, 4][rng.next_below(3) as usize],
        head_dim: [4usize, 8, 10][rng.next_below(3) as usize],
        page_positions: [1usize, 2, 3, 5, 8, 32][rng.next_below(6) as usize],
        base_len,
        forks,
        seed: rng.next_u64(),
    }
}

/// Paged-pool attention parity: sequences forked from a shared prefix
/// chain (CoW-diverged at random, unaligned split points) under random
/// page sizes attend identically — to f32 reassociation — to the scalar
/// reference over independently built single-page (contiguous) caches
/// holding the same rows. Pins both the page-run streaming and the
/// sharing/CoW machinery to the monolithic-layout semantics.
#[test]
fn prop_paged_pool_attention_matches_contiguous() {
    forall("paged attention parity", num_cases(10), gen_paged_case, |case| {
        let d_model = case.n_heads * case.head_dim;
        let max_seq = 32;
        let cfg = GptConfig {
            d_model,
            n_layers: 2,
            n_heads: case.n_heads,
            d_ff: 2 * d_model,
            max_seq,
            ..GptConfig::tiny()
        };
        let mut rng = Pcg64::seed_from_u64(case.seed);
        let row = |rng: &mut Pcg64| -> (Vec<f32>, Vec<f32>) {
            let k: Vec<f32> = (0..d_model).map(|_| rng.next_gaussian()).collect();
            let v: Vec<f32> = (0..d_model).map(|_| rng.next_gaussian()).collect();
            (k, v)
        };
        let base_rows: Vec<(Vec<f32>, Vec<f32>)> =
            (0..case.base_len).map(|_| row(&mut rng)).collect();
        let suffix_rows: Vec<Vec<(Vec<f32>, Vec<f32>)>> = case
            .forks
            .iter()
            .map(|&(_, n)| (0..n).map(|_| row(&mut rng)).collect())
            .collect();
        let append_all = |c: &mut KvCache, rows: &[(Vec<f32>, Vec<f32>)]| {
            for (k, v) in rows {
                for l in 0..cfg.n_layers {
                    c.append(l, k, v);
                }
                c.advance(1);
            }
        };

        // paged side: every sequence forks the shared base at its split
        let pool = armor::serve::KvPool::new(&cfg, case.page_positions, None)
            .map_err(|e| e.to_string())?;
        let mut base = pool.new_cache();
        append_all(&mut base, &base_rows);
        let paged: Vec<KvCache> = case
            .forks
            .iter()
            .zip(&suffix_rows)
            .map(|(&(split, _), suffix)| {
                let mut c = base.fork_prefix(split);
                append_all(&mut c, suffix);
                c
            })
            .collect();
        // contiguous side: single-page caches built independently
        let mono_pool =
            armor::serve::KvPool::new(&cfg, max_seq, None).map_err(|e| e.to_string())?;
        let contiguous: Vec<KvCache> = case
            .forks
            .iter()
            .zip(&suffix_rows)
            .map(|(&(split, _), suffix)| {
                let mut c = mono_pool.new_cache();
                append_all(&mut c, &base_rows[..split]);
                append_all(&mut c, suffix);
                c
            })
            .collect();

        let lens: Vec<usize> = case.forks.iter().map(|&(s, n)| s + n).collect();
        let paged_refs: Vec<&KvCache> = paged.iter().collect();
        let mono_refs: Vec<&KvCache> = contiguous.iter().collect();
        let q = Matrix::randn(lens.len(), d_model, &mut rng);
        let kern = AttnKernel::new(cfg.n_heads, cfg.head_dim());
        for layer in 0..cfg.n_layers {
            let blocked = kern.attend_batch(&paged_refs, layer, &q, &lens);
            let scalar = attend_batch_scalar(&mono_refs, layer, &q, &lens, cfg.n_heads);
            for i in 0..lens.len() {
                for c in 0..d_model {
                    let (b, s) = (blocked[(i, c)], scalar[(i, c)]);
                    if (b - s).abs() > 1e-5 * (1.0 + s.abs()) {
                        return Err(format!(
                            "page {} layer {layer} seq {i} (split {} len {}) col {c}: \
                             paged {b} vs contiguous {s}",
                            case.page_positions, case.forks[i].0, lens[i]
                        ));
                    }
                }
            }
            // the scalar route over the paged chains must agree bit-exactly
            // with the scalar route over the contiguous copies: paging and
            // CoW never change stored values, only their placement
            let scalar_paged = attend_batch_scalar(&paged_refs, layer, &q, &lens, cfg.n_heads);
            if scalar_paged.max_abs_diff(&scalar) != 0.0 {
                return Err(format!(
                    "layer {layer}: scalar-over-paged drifted from scalar-over-contiguous"
                ));
            }
        }
        Ok(())
    });
}

struct Q8Case {
    layer: Layer,
    group: usize,
    batch: usize,
}

fn gen_q8_case(rng: &mut Pcg64) -> Q8Case {
    Q8Case {
        layer: gen_layer(rng),
        group: [2usize, 4, 8, 16, 32][rng.next_below(5) as usize],
        batch: 1 + rng.next_below(80) as usize,
    }
}

/// The fused dequant q8 core matmul stays within the analytic int8 error
/// envelope of the f32 compressed matmul — per weight the quantization
/// error is at most `group_max/254 <= wmax/254`, so each output element
/// can drift by at most that times the L1 mass of its activation column —
/// across random shapes, scale-group sizes (ragged last groups included),
/// and batch widths. The blocked path must also stay bit-exact with its
/// scalar oracle, like the f32 path.
#[test]
fn prop_q8_core_matmul_close_to_f32() {
    forall("q8 core matmul", num_cases(12), gen_q8_case, |case| {
        let l = &case.layer;
        if l.w.cols % 4 != 0 {
            return Ok(());
        }
        let imp = l.w.hadamard(&l.w);
        let mask = mask_from_importance(&imp, Pattern::TWO_FOUR);
        let c = armor::sparsity::Compressed24::compress(&l.w, &mask)
            .map_err(|e| e.to_string())?;
        let q = c.quantize(case.group).map_err(|e| e.to_string())?;
        let mut rng = Pcg64::seed_from_u64(l.seed);
        let x = Matrix::randn(l.w.cols, case.batch, &mut rng);
        let f32_out = c.matmul(&x);
        let q8_out = q.matmul_q8(&x);
        if q8_out != q.matmul_q8_ref(&x) {
            return Err("blocked q8 drifted from its scalar oracle".into());
        }
        let wmax = c.values.iter().fold(0.0f32, |a, &v| a.max(v.abs()));
        for j in 0..case.batch {
            let l1: f32 = (0..l.w.cols).map(|i| x[(i, j)].abs()).sum();
            let tol = wmax / 254.0 * l1 * 1.5 + 1e-5;
            for i in 0..l.w.rows {
                let d = (q8_out[(i, j)] - f32_out[(i, j)]).abs();
                if d > tol {
                    return Err(format!(
                        "group {} ({}x{} b{}): out ({i},{j}) diff {d} > tol {tol}",
                        case.group, l.w.rows, l.w.cols, case.batch
                    ));
                }
            }
        }
        Ok(())
    });
}

/// Q8 paged attention matches f32 attention over the same rows within the
/// quantization envelope: per position the score shifts by at most
/// `D = Σ|q_h| · kmax/254 / √hd`, so softmax weights move by at most a
/// factor `e^{2D}`, and every V row carries its own `vmax/254` dequant
/// error — the bound is computed per case from the actual data. The
/// blocked q8 kernel must also agree bit-close with the scalar oracle
/// dequantizing the same codes (scalar-over-f32 stays the parity path).
#[test]
fn prop_q8_paged_attention_matches_f32_within_tol() {
    forall("q8 paged attention", num_cases(10), gen_paged_case, |case| {
        let d_model = case.n_heads * case.head_dim;
        let cfg = GptConfig {
            d_model,
            n_layers: 1,
            n_heads: case.n_heads,
            d_ff: 2 * d_model,
            max_seq: 32,
            ..GptConfig::tiny()
        };
        let f32_pool = armor::serve::KvPool::new(&cfg, case.page_positions, None)
            .map_err(|e| e.to_string())?;
        let q8_pool = armor::serve::KvPool::new_with_quant(
            &cfg,
            case.page_positions,
            None,
            armor::serve::KvQuant::Q8,
        )
        .map_err(|e| e.to_string())?;
        let mut rng = Pcg64::seed_from_u64(case.seed);
        let lens: Vec<usize> = case.forks.iter().map(|&(s, n)| (s + n).max(1)).collect();
        let mut kmax = 0.0f32;
        let mut vmax = 0.0f32;
        let mut f32_caches = Vec::new();
        let mut q8_caches = Vec::new();
        for &n in &lens {
            let mut cf = f32_pool.new_cache();
            let mut cq = q8_pool.new_cache();
            for _ in 0..n {
                let k: Vec<f32> = (0..d_model).map(|_| rng.next_gaussian()).collect();
                let v: Vec<f32> = (0..d_model).map(|_| rng.next_gaussian()).collect();
                kmax = k.iter().fold(kmax, |a, &x| a.max(x.abs()));
                vmax = v.iter().fold(vmax, |a, &x| a.max(x.abs()));
                cf.append(0, &k, &v);
                cq.append(0, &k, &v);
                cf.advance(1);
                cq.advance(1);
            }
            f32_caches.push(cf);
            q8_caches.push(cq);
        }
        let f32_refs: Vec<&KvCache> = f32_caches.iter().collect();
        let q8_refs: Vec<&KvCache> = q8_caches.iter().collect();
        let q = Matrix::randn(lens.len(), d_model, &mut rng);
        let kern = AttnKernel::new(case.n_heads, case.head_dim);
        let f32_out = kern.attend_batch(&f32_refs, 0, &q, &lens);
        let q8_out = kern.attend_batch(&q8_refs, 0, &q, &lens);
        // blocked-over-q8 vs scalar-over-the-same-dequantized-rows: the
        // fused dequant is a reassociation, not a value change
        let scalar_q8 = attend_batch_scalar(&q8_refs, 0, &q, &lens, case.n_heads);
        for i in 0..lens.len() {
            for c in 0..d_model {
                let (b, s) = (q8_out[(i, c)], scalar_q8[(i, c)]);
                if (b - s).abs() > 1e-5 * (1.0 + s.abs()) {
                    return Err(format!(
                        "page {} seq {i} col {c}: blocked q8 {b} vs scalar-over-q8 {s}",
                        case.page_positions
                    ));
                }
            }
        }
        for (i, &_n) in lens.iter().enumerate() {
            for h in 0..case.n_heads {
                let hd = case.head_dim;
                let q_l1: f32 = q.row(i)[h * hd..(h + 1) * hd].iter().map(|x| x.abs()).sum();
                let d_max = q_l1 * (kmax / 254.0) / (hd as f32).sqrt();
                let tol = ((2.0 * d_max).exp() - 1.0) * vmax + vmax / 254.0 + 1e-4;
                for t in 0..hd {
                    let d = (q8_out[(i, h * hd + t)] - f32_out[(i, h * hd + t)]).abs();
                    if d > tol {
                        return Err(format!(
                            "page {} seq {i} head {h} col {t}: q8 vs f32 diff {d} > tol {tol}",
                            case.page_positions
                        ));
                    }
                }
            }
        }
        Ok(())
    });
}

/// NoWag normalization always denormalizes back to the original matrix,
/// even with zero columns/rows and extreme scales.
#[test]
fn prop_normalization_roundtrip() {
    forall("normalize roundtrip", num_cases(20), gen_layer, |l| {
        let n = armor::normalize::nowag_normalize(&l.w);
        if !n.w_bar.all_finite() {
            return Err("non-finite W̄".into());
        }
        let back = armor::normalize::denormalize(&n.w_bar, &n.r1, &n.r2);
        let scale = l.w.data.iter().fold(0.0f32, |a, &x| a.max(x.abs())).max(1e-6);
        if back.max_abs_diff(&l.w) > 1e-3 * scale {
            return Err(format!("roundtrip error {}", back.max_abs_diff(&l.w)));
        }
        Ok(())
    });
}

struct ChunkCase {
    prompt: Vec<u16>,
    chunk: usize,
    page_positions: usize,
    /// leading tokens shared with a pre-registered template (0 = cold)
    share: usize,
    seed: u64,
}

fn gen_chunk_case(rng: &mut Pcg64) -> ChunkCase {
    let len = 2 + rng.next_below(24) as usize;
    ChunkCase {
        prompt: (0..len).map(|_| rng.next_below(250) as u16).collect(),
        chunk: 1 + rng.next_below(len as u32 + 3) as usize,
        page_positions: [2usize, 3, 4, 8][rng.next_below(4) as usize],
        share: rng.next_below(len as u32) as usize,
        seed: rng.next_u64(),
    }
}

/// Chunked prefill is bit-exact against the monolithic path for random
/// prompt lengths, chunk sizes (including ones straddling page boundaries),
/// page sizes, and on top of a prefix-cache hit: every logits row, the KV
/// pages (checked through a subsequent decode step), and the reused-prefix
/// suffix all agree bit for bit.
#[test]
fn prop_prefill_chunked_matches_monolithic() {
    let cfg = GptConfig {
        d_model: 32,
        n_layers: 2,
        n_heads: 2,
        d_ff: 64,
        max_seq: 32,
        ..GptConfig::tiny()
    };
    let model = GptModel::random_init(&cfg, &mut Pcg64::seed_from_u64(0xC4));
    let compiled = CompiledModel::compile(&model, None).unwrap();
    forall("chunked prefill parity", num_cases(10), gen_chunk_case, |case| {
        // monolithic reference on the same pool (same page tiling, so the
        // attention kernel streams identical runs — parity is bit-exact)
        let pool = armor::serve::KvPool::new(&cfg, case.page_positions, None)
            .map_err(|e| e.to_string())?;
        let mut mono = pool.new_cache();
        let full = compiled.prefill(&mut mono, &case.prompt);

        // cold chunked prefill: every chunk's logits rows line up
        let mut cache = pool.new_cache();
        let mut cursor = 0usize;
        while cursor < case.prompt.len() {
            let n = case.chunk.min(case.prompt.len() - cursor);
            let logits = compiled.prefill(&mut cache, &case.prompt[cursor..cursor + n]);
            for i in 0..logits.rows {
                if logits.row(i) != full.row(cursor + i) {
                    return Err(format!(
                        "chunk {} pages {}: row {} drifted",
                        case.chunk,
                        case.page_positions,
                        cursor + i
                    ));
                }
            }
            cursor += n;
        }
        if cache.len() != mono.len() {
            return Err(format!("cache length {} vs {}", cache.len(), mono.len()));
        }
        // the chunk-built KV pages decode identically to the monolithic ones
        let tok = armor::model::argmax(full.row(full.rows - 1)) as u16;
        let mut mono2 = mono.clone();
        if compiled.decode_step(&mut cache, tok) != compiled.decode_step(&mut mono2, tok) {
            return Err("decode after chunked prefill drifted".into());
        }

        // warm path: register a template sharing `share` leading tokens
        // (tail forced to diverge), then attach + chunked suffix prefill
        let mut reg = armor::serve::PrefixRegistry::new(pool.clone(), 4);
        let mut rng = Pcg64::seed_from_u64(case.seed);
        let mut template = case.prompt[..case.share].to_vec();
        template.extend((0..3).map(|_| 250 + rng.next_below(6) as u16));
        let (t_cache, _, _) = compiled.prefill_reuse(&mut reg, &pool, &template);
        drop(t_cache);
        let (mut warm, reused) = CompiledModel::prefill_attach(&mut reg, &pool, &case.prompt);
        if reused >= case.prompt.len() || reused > case.share {
            return Err(format!("reuse {reused} out of range (share {})", case.share));
        }
        let last = compiled.prefill_chunked(&mut warm, &case.prompt[reused..], case.chunk);
        for i in 0..last.rows {
            if last.row(i) != full.row(full.rows - last.rows + i) {
                return Err(format!(
                    "warm chunked prefill (reused {reused}) drifted at suffix row {i}"
                ));
            }
        }
        Ok(())
    });
}

struct StarveCase {
    n_low: usize,
    low_prio: u8,
    prompt_len: usize,
    seed: u64,
}

fn gen_starve_case(rng: &mut Pcg64) -> StarveCase {
    StarveCase {
        n_low: 1 + rng.next_below(3) as usize,
        low_prio: 1 + rng.next_below(3) as u8,
        prompt_len: 2 + rng.next_below(5) as usize,
        seed: rng.next_u64(),
    }
}

/// Starvation-freedom of the priority scheduler: with a saturating
/// high-priority stream (one new urgent request per engine step, a batch
/// of one), aging must still complete every low-priority request within a
/// bounded number of steps — `(PRIORITY_LANES - 1) · AGING_TICKS` ticks to
/// reach lane 0 plus a bounded FIFO drain ahead of later arrivals.
#[test]
fn prop_priority_aging_prevents_starvation() {
    use armor::serve::{Engine, EngineConfig, SchedPolicy};
    let cfg = GptConfig {
        d_model: 32,
        n_layers: 2,
        n_heads: 2,
        d_ff: 64,
        max_seq: 32,
        ..GptConfig::tiny()
    };
    let model = GptModel::random_init(&cfg, &mut Pcg64::seed_from_u64(0x5A));
    let compiled = CompiledModel::compile(&model, None).unwrap();
    forall("priority aging starvation-freedom", num_cases(6), gen_starve_case, |case| {
        let mut engine = Engine::new(
            compiled.clone(),
            EngineConfig { max_batch: 1, policy: SchedPolicy::Priority, ..EngineConfig::default() },
        )
        .map_err(|e| e.to_string())?;
        let mut rng = Pcg64::seed_from_u64(case.seed);
        let lows: Vec<_> = (0..case.n_low)
            .map(|_| {
                let p: Vec<u16> =
                    (0..case.prompt_len).map(|_| rng.next_below(256) as u16).collect();
                engine.submit_with(&p, 1, case.low_prio, None)
            })
            .collect();
        // generous bound: full aging ladder + the in-flight lane-0 queue
        let bound = 16 * (armor::serve::PRIORITY_LANES as u64 * armor::serve::AGING_TICKS
            + case.n_low as u64) as usize;
        let mut steps = 0usize;
        while !lows.iter().all(|&id| engine.completed(id)) {
            if steps >= bound {
                return Err(format!(
                    "low-priority (lane {}) request starved after {bound} steps",
                    case.low_prio
                ));
            }
            // the urgent stream never pauses
            let p: Vec<u16> = (0..3).map(|_| rng.next_below(256) as u16).collect();
            engine.submit_with(&p, 1, 0, None);
            engine.step();
            steps += 1;
        }
        // the stream really was saturating: urgent traffic kept completing
        let report = engine.drain();
        if report.requests.len() < steps {
            return Err(format!(
                "only {} of {} submitted requests completed",
                report.requests.len(),
                steps
            ));
        }
        Ok(())
    });
}

struct SpecCase {
    /// prompt + generation budget per request
    reqs: Vec<(Vec<u16>, usize)>,
    spec_k: usize,
    page_positions: usize,
    q8_kv: bool,
}

fn gen_spec_case(rng: &mut Pcg64) -> SpecCase {
    let n = 1 + rng.next_below(3) as usize;
    let reqs = (0..n)
        .map(|_| {
            let len = 2 + rng.next_below(24) as usize;
            let prompt = (0..len).map(|_| rng.next_below(250) as u16).collect();
            (prompt, 1 + rng.next_below(12) as usize)
        })
        .collect();
    SpecCase {
        reqs,
        spec_k: 1 + rng.next_below(8) as usize,
        page_positions: [2usize, 3, 4, 8][rng.next_below(4) as usize],
        q8_kv: rng.next_below(2) == 1,
    }
}

/// Speculative decoding is an acceleration, never a behavior change: for
/// random prompt sets, draft lengths, page sizes, and KV dtypes, a 2:4
/// pruned model served with `spec: Some(k)` — int8-plane drafts on a CoW
/// KV fork, one f32 batch verify on the main chain — generates exactly
/// the token streams of the plain one-token-per-step f32 engine. The
/// pruned model is the adversarial case: its int8 draft plane genuinely
/// disagrees with the f32 target on some steps, so acceptance < 100% and
/// the rejection/rollback path is exercised, not just the happy path.
#[test]
fn prop_speculative_decode_bit_identical() {
    use armor::serve::{Engine, EngineConfig, KvQuant};
    let cfg = GptConfig {
        d_model: 32,
        n_layers: 2,
        n_heads: 2,
        d_ff: 64,
        max_seq: 48,
        ..GptConfig::tiny()
    };
    let mut rng = Pcg64::seed_from_u64(0x5EC);
    let model = GptModel::random_init(&cfg, &mut rng);
    let seqs: Vec<Vec<u16>> = (0..2)
        .map(|i| {
            let mut r = Pcg64::seed_from_u64(0xCA11B + i);
            (0..24).map(|_| r.next_below(250) as u16).collect()
        })
        .collect();
    let stats = calibrate(&model, &seqs, false);
    let job = PruneJob { method: Method::NoWagP, pattern: Pattern::TWO_FOUR, seed: 7, use_xla: false };
    let (pruned, _) = prune_model(&model, &stats, &job, None);
    let compiled = CompiledModel::compile(&pruned, None).unwrap();
    forall("speculative decode parity", num_cases(8), gen_spec_case, |case| {
        let base = EngineConfig {
            max_batch: 2,
            page_positions: case.page_positions,
            kv_quant: if case.q8_kv { KvQuant::Q8 } else { KvQuant::F32 },
            ..EngineConfig::default()
        };
        let run = |cfg: EngineConfig| -> Result<Vec<Vec<u16>>, String> {
            let mut engine = Engine::new(compiled.clone(), cfg).map_err(|e| e.to_string())?;
            let ids: Vec<_> =
                case.reqs.iter().map(|(p, n)| engine.submit(p, *n)).collect();
            let report = engine.drain();
            ids.iter()
                .map(|id| {
                    report
                        .requests
                        .iter()
                        .find(|r| r.id == *id)
                        .map(|r| r.generated.clone())
                        .ok_or_else(|| format!("request {id:?} never completed"))
                })
                .collect()
        };
        let plain = run(EngineConfig { spec: None, ..base })?;
        let spec = run(EngineConfig { spec: Some(case.spec_k), ..base })?;
        for (i, (p, s)) in plain.iter().zip(&spec).enumerate() {
            if p != s {
                return Err(format!(
                    "k {} pages {} q8kv {}: request {i} diverged\n  plain {:?}\n  spec  {:?}",
                    case.spec_k, case.page_positions, case.q8_kv, p, s
                ));
            }
        }
        Ok(())
    });
}

struct PreemptCase {
    /// low-urgency requests submitted first: (prompt, max_new)
    init: Vec<(Vec<u16>, usize)>,
    /// high-urgency burst submitted after a few steps
    burst: Vec<(Vec<u16>, usize)>,
    policy: armor::serve::SchedPolicy,
    page_positions: usize,
    /// page budget sized for this many worst-case sequences
    budget_seqs: usize,
    steps_before_burst: usize,
    prefix_sharing: bool,
}

fn gen_preempt_case(rng: &mut Pcg64) -> PreemptCase {
    use armor::serve::SchedPolicy;
    let policy = match rng.next_below(4) {
        0 => SchedPolicy::Fifo, // degenerate: in-flight always outranks waiting
        1 | 2 => SchedPolicy::Priority,
        _ => SchedPolicy::Deadline,
    };
    let reqs = |n: usize, rng: &mut Pcg64| -> Vec<(Vec<u16>, usize)> {
        (0..n)
            .map(|_| {
                let len = 2 + rng.next_below(7) as usize;
                let p = (0..len).map(|_| rng.next_below(250) as u16).collect();
                (p, 4 + rng.next_below(7) as usize)
            })
            .collect()
    };
    let n_init = 1 + rng.next_below(2) as usize;
    let n_burst = 1 + rng.next_below(3) as usize;
    PreemptCase {
        init: reqs(n_init, rng),
        burst: reqs(n_burst, rng),
        policy,
        page_positions: [2usize, 3, 4, 8][rng.next_below(4) as usize],
        budget_seqs: 1 + rng.next_below(2) as usize,
        steps_before_burst: 1 + rng.next_below(2) as usize,
        prefix_sharing: rng.next_below(2) == 1,
    }
}

/// Preemption is a scheduling decision, never a behavior change: for random
/// eviction-forcing budgets, policies, page sizes, and prompt sets, every
/// request — evicted and re-admitted or not — generates exactly the tokens
/// of an uninterrupted solo run, and the pool's reservation accounting ends
/// flat. The case shape forces pressure: low-urgency requests admit first
/// under a budget of 1–2 worst-case sequences, then a high-urgency burst
/// arrives (priority lane 0 / tight EDF deadline) and must evict them.
#[test]
fn prop_preempt_resume_bit_identical() {
    use armor::serve::{Engine, EngineConfig, KvPool, SchedPolicy};
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::time::Duration;
    let cfg = GptConfig {
        d_model: 32,
        n_layers: 2,
        n_heads: 2,
        d_ff: 64,
        max_seq: 32,
        ..GptConfig::tiny()
    };
    let model = GptModel::random_init(&cfg, &mut Pcg64::seed_from_u64(0x9E));
    let compiled = CompiledModel::compile(&model, None).unwrap();
    let evictions = AtomicUsize::new(0);
    forall("preempt/resume parity", num_cases(8), gen_preempt_case, |case| {
        let probe = KvPool::new(&compiled.cfg, case.page_positions, None)
            .map_err(|e| e.to_string())?;
        let worst = case
            .init
            .iter()
            .chain(&case.burst)
            .map(|(p, n)| probe.pages_for_seq((p.len() + n - 1).min(compiled.cfg.max_seq)))
            .max()
            .unwrap();
        let mut engine = Engine::new(
            compiled.clone(),
            EngineConfig {
                max_batch: 4,
                page_positions: case.page_positions,
                kv_budget_bytes: Some(case.budget_seqs * worst * probe.page_bytes()),
                prefix_sharing: case.prefix_sharing,
                policy: case.policy,
                ..EngineConfig::default()
            },
        )
        .map_err(|e| e.to_string())?;
        // low urgency: worst priority lane, no deadline (EDF sorts last)
        let mut ids = Vec::new();
        for (p, n) in &case.init {
            ids.push((engine.submit_with(p, *n, 3, None), p, *n));
        }
        for _ in 0..case.steps_before_burst {
            engine.step();
        }
        // high urgency: lane 0 / tight deadline — must displace the above
        for (p, n) in &case.burst {
            ids.push((engine.submit_with(p, *n, 0, Some(Duration::from_millis(5))), p, *n));
        }
        let report = engine.drain();
        evictions.fetch_add(report.preempt_evictions, Ordering::Relaxed);
        for (id, prompt, max_new) in ids {
            let r = report
                .requests
                .iter()
                .find(|r| r.id == id)
                .ok_or_else(|| format!("request {id:?} never completed"))?;
            let solo = compiled.generate(prompt, max_new);
            if r.generated[..] != solo[prompt.len()..] {
                return Err(format!(
                    "policy {:?} pages {} budget {}x: request {id:?} diverged after preemption",
                    case.policy, case.page_positions, case.budget_seqs
                ));
            }
            if r.abort_reason.is_some() {
                return Err(format!("request {id:?} spuriously aborted"));
            }
        }
        if !case.prefix_sharing {
            // without retained prefix chains the pool must end exactly flat
            if engine.pool().pages_reserved() != 0 || engine.pool().pages_allocated() != 0 {
                return Err(format!(
                    "pool not flat after drain: {} reserved, {} allocated",
                    engine.pool().pages_reserved(),
                    engine.pool().pages_allocated()
                ));
            }
        }
        if engine.pool().release_underflows() != 0 {
            return Err("release underflow during preemption churn".into());
        }
        Ok(())
    });
    assert!(
        evictions.load(Ordering::Relaxed) > 0,
        "the case shape is eviction-forcing; at least one case must preempt"
    );
}
