//! Cross-layer integration tests: the Rust runtime against the real AOT
//! artifacts and the build-time-trained model. These tests skip (pass
//! trivially with a notice) when `artifacts/` has not been built, so
//! `cargo test` works before `make artifacts`.

#[cfg(feature = "pjrt")]
use armor::armor::{ArmorConfig, ArmorOptimizer, ContinuousOpt};
#[cfg(feature = "pjrt")]
use armor::coordinator::{calibrate, prune_model, PruneJob};
use armor::data::{sample_calibration, tokenize};
use armor::model::GptModel;
#[cfg(feature = "pjrt")]
use armor::model::NoCapture;
#[cfg(feature = "pjrt")]
use armor::runtime::{gpt_nll_xla, ArmorXlaOptimizer, Runtime};
#[cfg(feature = "pjrt")]
use armor::sparsity::Pattern;
#[cfg(feature = "pjrt")]
use armor::tensor::Matrix;
use armor::util::rng::Pcg64;
use std::path::Path;

#[cfg(feature = "pjrt")]
fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("[skip] artifacts/ not built — run `make artifacts`");
        None
    }
}

fn model_path() -> Option<std::path::PathBuf> {
    let p = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/model/tiny.tsr");
    if p.exists() {
        Some(p)
    } else {
        eprintln!("[skip] trained model not found — run `make artifacts`");
        None
    }
}

/// The trained model loads in Rust and its native NLL matches the value
/// JAX recorded at training time — the strongest cross-language parity
/// check in the repo (same weights, independent forward implementations).
#[test]
fn trained_model_nll_matches_jax() {
    let Some(path) = model_path() else { return };
    let model = GptModel::load(&path).unwrap();
    let bundle = armor::io::TensorBundle::load(&path).unwrap();
    let jax_nll = bundle.meta.get("eval_nll").as_f64().expect("eval_nll in meta");

    // Reproduce the eval: random corpus windows; distributions match, exact
    // windows don't, so compare within a tolerance band.
    let corpus = std::fs::read_to_string(
        Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/corpus/train.txt"),
    )
    .unwrap();
    let tokens = tokenize(&corpus);
    let mut rng = Pcg64::seed_from_u64(123);
    let seqs = sample_calibration(&tokens, model.cfg.max_seq, 8, &mut rng);
    let mut total = 0.0;
    for s in &seqs {
        total += model.nll(s);
    }
    let rust_nll = total / seqs.len() as f64;
    assert!(
        (rust_nll - jax_nll).abs() < 0.35,
        "rust nll {rust_nll:.4} vs jax {jax_nll:.4} — forward passes diverge"
    );
}

/// The `gpt_nll_*` artifact executed via PJRT matches the native forward on
/// identical sequences (tight tolerance: same weights, same math, two
/// execution engines).
#[cfg(feature = "pjrt")]
#[test]
fn gpt_nll_artifact_matches_native() {
    let (Some(dir), Some(mpath)) = (artifacts_dir(), model_path()) else { return };
    let rt = Runtime::load(&dir).unwrap();
    if !rt.has("gpt_nll_b8") {
        eprintln!("[skip] gpt_nll_b8 artifact missing");
        return;
    }
    let model = GptModel::load(&mpath).unwrap();
    let mut rng = Pcg64::seed_from_u64(5);
    let batch: Vec<Vec<u16>> = (0..8)
        .map(|_| (0..model.cfg.max_seq).map(|_| rng.next_below(256) as u16).collect())
        .collect();
    let xla_nll = gpt_nll_xla(&rt, "gpt_nll_b8", &model, &batch).unwrap();
    for (i, seq) in batch.iter().enumerate() {
        let native = model.nll(seq);
        assert!(
            (native - xla_nll[i] as f64).abs() < 5e-3 * native.max(1.0),
            "seq {i}: native {native:.5} vs xla {:.5}",
            xla_nll[i]
        );
    }
}

/// The XLA cont_steps path and the native Adam path optimize the same
/// objective: from identical inits, both reduce the proxy loss and land in
/// the same neighbourhood.
#[cfg(feature = "pjrt")]
#[test]
fn xla_optimizer_tracks_native() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::load(&dir).unwrap();
    let artifact = "cont_steps_128x128_b32";
    if !rt.has(artifact) {
        eprintln!("[skip] {artifact} missing");
        return;
    }
    let mut rng = Pcg64::seed_from_u64(9);
    let w = Matrix::randn(128, 128, &mut rng);
    let d: Vec<f32> = (0..128).map(|_| rng.next_f32() + 0.1).collect();
    let cfg = ArmorConfig {
        d_block: 32,
        n_iters: 30,
        optimizer: ContinuousOpt::Adam { lr: 1e-3 },
        sparse_update: false, // isolate the continuous path for comparison
        ..Default::default()
    };

    let mut xla_opt =
        ArmorXlaOptimizer::new(&rt, &w, &d, &cfg, Pcg64::seed_from_u64(1)).unwrap();
    xla_opt.run(30).unwrap();
    let xla_loss = xla_opt.current_loss();
    let xla_init = xla_opt.initial_loss;

    let mut native_opt = ArmorOptimizer::new(&w, &d, &cfg, Pcg64::seed_from_u64(1));
    native_opt.run(30);
    let native_loss = native_opt.current_loss();

    assert!(xla_loss < xla_init, "XLA path failed to descend: {xla_init} -> {xla_loss}");
    let rel = (xla_loss - native_loss).abs() / native_loss;
    assert!(rel < 0.02, "XLA {xla_loss} vs native {native_loss} ({})", rel);
}

/// Full pipeline through the XLA hot path: prune the trained model with
/// ARMOR using the artifacts, and confirm it beats NoWag-P on weighted
/// error while producing a working model.
#[cfg(feature = "pjrt")]
#[test]
fn xla_pipeline_end_to_end() {
    let (Some(dir), Some(mpath)) = (artifacts_dir(), model_path()) else { return };
    let rt = Runtime::load(&dir).unwrap();
    let model = GptModel::load(&mpath).unwrap();
    let corpus = std::fs::read_to_string(
        Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/corpus/train.txt"),
    )
    .unwrap();
    let tokens = tokenize(&corpus);
    let mut rng = Pcg64::seed_from_u64(77);
    let seqs = sample_calibration(&tokens, model.cfg.max_seq, 4, &mut rng);
    let stats = calibrate(&model, &seqs, false);

    let cfg = ArmorConfig { d_block: 32, n_iters: 40, ..Default::default() };
    let job = PruneJob {
        method: armor::baselines::Method::Armor(cfg),
        pattern: Pattern::TWO_FOUR,
        seed: 2,
        use_xla: true,
    };
    let (pruned, armor_rep) = prune_model(&model, &stats, &job, Some(&rt));

    let nowag_job = PruneJob {
        method: armor::baselines::Method::NoWagP,
        pattern: Pattern::TWO_FOUR,
        seed: 2,
        use_xla: false,
    };
    let (_, nowag_rep) = prune_model(&model, &stats, &nowag_job, None);

    assert!(
        armor_rep.total_weighted_err < nowag_rep.total_weighted_err,
        "armor {} >= nowag {}",
        armor_rep.total_weighted_err,
        nowag_rep.total_weighted_err
    );
    let logits = pruned.forward(&seqs[0], &mut NoCapture);
    assert!(logits.all_finite());
    // every ARMOR layer recorded its losses through the XLA path
    assert!(armor_rep.layers.iter().all(|l| l.initial_loss.is_some()));
}
