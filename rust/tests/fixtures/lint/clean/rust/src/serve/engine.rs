//! Clean engine fixture: pragmas honored, test code and doc examples
//! exempt.
//!
//! ```
//! let x = v.pop().unwrap(); // doc-comment example: never a violation
//! ```

pub fn admit(q: &mut Vec<u32>) -> u32 {
    // lint: allow(PANIC_UNWRAP) reason="queue checked non-empty by the caller"
    q.pop().unwrap()
}

// lint: allow(PANIC_INDEX) reason="i is clamped by the caller"
pub fn pick(v: &[u32], i: usize) -> u32 {
    v[i]
}

pub fn register(r: &Reg) {
    let c = r.counter("armor_requests_total", &[], "Completed requests.");
    let _ = c;
}

#[cfg(test)]
mod tests {
    #[test]
    fn test_code_is_exempt() {
        let mut v: Vec<u32> = vec![3];
        assert_eq!(v[0], 3);
        v.pop().unwrap();
        panic!("test-side panics are fine");
    }
}
