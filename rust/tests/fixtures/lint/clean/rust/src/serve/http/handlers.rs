pub fn bad(msg: &str) -> Response {
    Response::error(400, "bad_request", msg)
}
