pub const FP_KV_ALLOC: &str = "kv_alloc";
