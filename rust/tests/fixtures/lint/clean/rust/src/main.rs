fn main() {
    let args = Args::parse();
    let batch = args.get_usize("batch", 8);
    let _ = batch;
}
