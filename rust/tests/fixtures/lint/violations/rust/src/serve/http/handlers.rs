pub fn teapot() -> Response {
    Response::error(418, "teapot", "short and stout")
}

pub fn bad(msg: &str) -> Response {
    Response::error(400, "bad_request", msg)
}
