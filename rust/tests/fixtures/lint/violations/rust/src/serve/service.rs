pub fn typod(v: &mut Vec<u32>) -> u32 {
    // lint: allow(PANIC_UNWRP) reason="typo'd rule id suppresses nothing"
    v.pop().unwrap()
}

pub fn malformed(v: &mut Vec<u32>) -> u32 {
    // lint: allow(PANIC_UNWRAP)
    v.pop().unwrap()
}
