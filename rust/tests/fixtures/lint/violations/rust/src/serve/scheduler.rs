pub fn covered_then_not(v: &mut Vec<u32>) -> (u32, u32) {
    // lint: allow(PANIC_UNWRAP) reason="first pop checked by the caller"
    let a = v.pop().unwrap();
    let b = v.pop().unwrap();
    (a, b)
}
