pub fn broken(v: &mut Vec<u32>, i: usize) -> u32 {
    let a = v.pop().unwrap();
    let b = v[i];
    panic!("kaboom {a} {b}");
}

pub fn register(r: &Reg) {
    let c = r.counter("armor_undocumented_total", &[], "never documented");
    let _ = c;
}
