pub fn hook() {
    unsafe {
        install();
    }
}
