pub const FP_TEST: &str = "test_site";
