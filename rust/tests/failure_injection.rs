//! Failure-injection and edge-case tests: every loader and pipeline entry
//! point must fail loudly and cleanly on corrupted inputs, never panic or
//! silently mis-read.

use armor::io::TensorBundle;
use armor::model::{GptConfig, GptModel};
use armor::sparsity::Pattern;
use armor::tensor::Matrix;
use armor::util::json::Json;
use armor::util::rng::Pcg64;
use std::io::Write;

fn tmp(name: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("armor_fi_{}_{}", std::process::id(), name))
}

#[test]
fn truncated_tsr_rejected() {
    let path = tmp("trunc.tsr");
    let mut b = TensorBundle::new();
    b.insert_matrix("w", &Matrix::ones(8, 8));
    b.save(&path).unwrap();
    // chop off half the payload
    let bytes = std::fs::read(&path).unwrap();
    std::fs::write(&path, &bytes[..bytes.len() - 100]).unwrap();
    assert!(TensorBundle::load(&path).is_err());
    std::fs::remove_file(&path).ok();
}

#[test]
fn tsr_header_with_out_of_bounds_offset_rejected() {
    let path = tmp("oob.tsr");
    let header = r#"{"tensors": {"w": {"shape": [1000, 1000], "offset": 0}}, "meta": {}}"#;
    let mut f = std::fs::File::create(&path).unwrap();
    f.write_all(b"TSR1").unwrap();
    f.write_all(&(header.len() as u64).to_le_bytes()).unwrap();
    f.write_all(header.as_bytes()).unwrap();
    f.write_all(&[0u8; 16]).unwrap(); // only 4 floats of payload
    drop(f);
    assert!(TensorBundle::load(&path).is_err());
    std::fs::remove_file(&path).ok();
}

#[test]
fn tsr_garbage_header_rejected() {
    let path = tmp("garbage.tsr");
    let mut f = std::fs::File::create(&path).unwrap();
    f.write_all(b"TSR1").unwrap();
    f.write_all(&(10u64).to_le_bytes()).unwrap();
    f.write_all(b"not json!!").unwrap();
    drop(f);
    assert!(TensorBundle::load(&path).is_err());
    std::fs::remove_file(&path).ok();
}

#[test]
fn model_load_rejects_wrong_shapes() {
    let mut rng = Pcg64::seed_from_u64(0);
    let cfg = GptConfig { d_model: 32, n_layers: 1, n_heads: 2, d_ff: 64, max_seq: 16, ..GptConfig::tiny() };
    let model = GptModel::random_init(&cfg, &mut rng);
    let path = tmp("badshape.tsr");
    // save with one tensor transposed
    let mut b = TensorBundle::new();
    for (name, m) in &model.tensors {
        if name == "l0.mlp.up" {
            b.insert_matrix(name, &m.transpose());
        } else {
            b.insert_matrix(name, m);
        }
    }
    b.meta = Json::obj(vec![("config", cfg.to_json())]);
    b.save(&path).unwrap();
    let err = GptModel::load(&path).unwrap_err().to_string();
    assert!(err.contains("l0.mlp.up"), "unhelpful error: {err}");
    std::fs::remove_file(&path).ok();
}

#[test]
fn model_load_rejects_missing_config() {
    let path = tmp("nocfg.tsr");
    let mut b = TensorBundle::new();
    b.insert_matrix("tok_embed", &Matrix::ones(4, 4));
    b.save(&path).unwrap();
    assert!(GptModel::load(&path).is_err());
    std::fs::remove_file(&path).ok();
}

/// Exercises the real PJRT client's compile-time (not load-time) failure;
/// the default build ships the stub runtime, which has no executables.
#[cfg(feature = "pjrt")]
#[test]
fn manifest_with_missing_hlo_file_errors_at_compile_not_load() {
    let dir = tmp("mani");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(
        dir.join("manifest.json"),
        r#"{"artifacts": [{"name": "ghost", "path": "ghost.hlo.txt",
            "input_shapes": [], "output_shapes": [], "meta": {}}]}"#,
    )
    .unwrap();
    let rt = armor::runtime::Runtime::load(&dir).unwrap();
    assert!(rt.has("ghost"));
    assert!(rt.executable("ghost").is_err()); // fails cleanly, no panic
    std::fs::remove_dir_all(&dir).ok();
}

/// Default build: the PJRT runtime is feature-gated; loading reports the
/// disabled feature as a clean error instead of panicking.
#[cfg(not(feature = "pjrt"))]
#[test]
fn runtime_disabled_without_pjrt_feature() {
    let dir = tmp("mani_stub");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("manifest.json"), r#"{"artifacts": []}"#).unwrap();
    let err = armor::runtime::Runtime::load(&dir).unwrap_err().to_string();
    assert!(err.contains("pjrt"), "unhelpful error: {err}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
#[should_panic(expected = "shape change")]
fn model_set_rejects_shape_change() {
    let mut rng = Pcg64::seed_from_u64(1);
    let cfg = GptConfig { d_model: 32, n_layers: 1, n_heads: 2, d_ff: 64, max_seq: 16, ..GptConfig::tiny() };
    let mut model = GptModel::random_init(&cfg, &mut rng);
    model.set("l0.attn.wq", Matrix::ones(16, 16));
}

#[test]
fn pattern_parse_rejects_degenerate() {
    for bad in ["0:0", "4:2", "abc", "2:", ":4", "-1:4", "150%x"] {
        assert!(Pattern::parse(bad).is_none(), "{bad} accepted");
    }
}

#[test]
fn prune_with_degenerate_calibration_stays_finite() {
    // all-zero activation stats: every importance ties; pipeline must not
    // NaN or violate the pattern
    let mut rng = Pcg64::seed_from_u64(2);
    let w = Matrix::randn(16, 32, &mut rng);
    let stats = armor::baselines::CalibStats {
        x_sq_norms: vec![0.0; 32],
        gram: None,
        n_samples: 0,
    };
    for method in [
        armor::baselines::Method::Wanda,
        armor::baselines::Method::NoWagP,
        armor::baselines::Method::Armor(armor::armor::ArmorConfig {
            d_block: 8,
            n_iters: 5,
            ..Default::default()
        }),
    ] {
        let out = armor::baselines::prune_layer(&w, &stats, &method, Pattern::TWO_FOUR, &mut rng);
        assert!(out.w_hat.all_finite(), "{}", out.method);
    }
}

#[test]
fn prune_survives_pathological_weights() {
    // zero matrix, rank-1 matrix, huge dynamic range
    let mut rng = Pcg64::seed_from_u64(3);
    let d = vec![1.0f32; 16];
    let cases: Vec<Matrix> = vec![
        Matrix::zeros(8, 16),
        {
            let u = Matrix::randn(8, 1, &mut rng);
            let v = Matrix::randn(1, 16, &mut rng);
            u.matmul(&v)
        },
        {
            let mut m = Matrix::randn(8, 16, &mut rng);
            m[(0, 0)] = 1e20;
            m[(7, 15)] = 1e-20;
            m
        },
    ];
    for (i, w) in cases.iter().enumerate() {
        let cfg = armor::armor::ArmorConfig { d_block: 8, n_iters: 5, ..Default::default() };
        let res = armor::armor::prune_matrix(w, &d, &cfg, &mut Pcg64::seed_from_u64(4));
        assert!(res.final_loss.is_finite(), "case {i}");
        assert!(res.final_loss <= res.initial_loss * (1.0 + 1e-6), "case {i}");
        assert!(res.factorization.mask.satisfies_nm(2, 4), "case {i}");
    }
}

#[test]
fn empty_calibration_batch_is_rejected_by_sampler() {
    let tokens: Vec<u16> = (0..10).collect();
    let result = std::panic::catch_unwind(|| {
        let mut rng = Pcg64::seed_from_u64(0);
        armor::data::sample_calibration(&tokens, 64, 4, &mut rng)
    });
    assert!(result.is_err(), "sampler must reject streams shorter than seq_len");
}
