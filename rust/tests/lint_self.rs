//! Self-tests for `armor lint` (DESIGN.md §12): per-rule fixture trees
//! with known `(file, line)` anchors, exact-once pragma accounting, CLI
//! exit codes and the JSON artifact, and — the strongest check — the
//! repository tree itself as the largest clean fixture.

use std::path::{Path, PathBuf};
use std::process::Command;

use armor::analysis::{run, LintReport, RULES};

fn fixture(name: &str) -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("tests").join("fixtures").join("lint").join(name)
}

fn repo_root() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("the crate sits one level under the repo root")
        .to_path_buf()
}

fn has(r: &LintReport, path: &str, line: u32, rule: &str) -> bool {
    r.violations.iter().any(|v| v.path == path && v.line == line && v.rule == rule)
}

#[test]
fn clean_fixture_lints_clean_with_pragmas_honored_exactly_once() {
    let r = run(&fixture("clean")).expect("lint run");
    assert!(r.clean(), "unexpected violations:\n{}", r.render(true));
    // One standalone next-line pragma and one fn-scope pragma, each
    // suppressing exactly the violation written under it.
    assert_eq!(r.pragmas.len(), 2, "{:?}", r.pragmas);
    assert!(r.pragmas.iter().all(|p| p.used), "unused pragma: {:?}", r.pragmas);
    assert_eq!(r.pragmas.iter().filter(|p| p.rule == "PANIC_UNWRAP").count(), 1);
    assert_eq!(r.pragmas.iter().filter(|p| p.rule == "PANIC_INDEX").count(), 1);
}

#[test]
fn violations_fixture_fires_every_rule_at_its_known_span() {
    let r = run(&fixture("violations")).expect("lint run");
    let expected: &[(&str, u32, &str)] = &[
        ("API.md", 5, "DRIFT_SLUG"),                        // ghost_slug never emitted
        ("API.md", 13, "DRIFT_METRIC"),                     // documented, never registered
        ("README.md", 6, "DRIFT_FLAG"),                     // --ghost-flag never parsed
        ("rust/src/main.rs", 4, "DRIFT_FLAG"),              // parsed, undocumented
        ("rust/src/obs/failpoint.rs", 1, "DRIFT_FAILPOINT"),
        ("rust/src/serve/engine.rs", 2, "PANIC_UNWRAP"),
        ("rust/src/serve/engine.rs", 3, "PANIC_INDEX"),
        ("rust/src/serve/engine.rs", 4, "PANIC_MACRO"),
        ("rust/src/serve/engine.rs", 8, "DRIFT_METRIC"),    // registered, undocumented
        ("rust/src/serve/http/handlers.rs", 2, "DRIFT_SLUG"),
        ("rust/src/serve/http/server.rs", 2, "UNSAFE_SAFETY"),
        ("rust/src/serve/kv_pool.rs", 4, "ORDERING_COMMENT"),
        ("rust/src/serve/scheduler.rs", 4, "PANIC_UNWRAP"), // pragma covers line 3 only
        ("rust/src/serve/service.rs", 2, "PRAGMA_UNKNOWN"),
        ("rust/src/serve/service.rs", 3, "PANIC_UNWRAP"),   // typo'd pragma suppressed nothing
        ("rust/src/serve/service.rs", 7, "PRAGMA_MALFORMED"),
        ("rust/src/serve/service.rs", 8, "PANIC_UNWRAP"),
    ];
    for &(path, line, rule) in expected {
        assert!(has(&r, path, line, rule), "missing {path}:{line} {rule}; got:\n{}", r.render(false));
    }
    assert_eq!(r.violations.len(), expected.len(), "extra findings:\n{}", r.render(false));
    // Every registered rule id fires somewhere in this fixture.
    for (id, _) in RULES {
        assert!(r.violations.iter().any(|v| v.rule == *id), "rule {id} never fired");
    }
    // The scheduler pragma was honored (for line 3) even though line 4
    // still violated — scope is exactly one line, not "the rest of fn".
    let sched: Vec<_> = r.pragmas.iter().filter(|p| p.path.ends_with("scheduler.rs")).collect();
    assert_eq!(sched.len(), 1);
    assert!(sched[0].used);
    // Violations come out sorted by (path, line, rule) for stable diffs.
    let keys: Vec<_> = r.violations.iter().map(|v| (v.path.clone(), v.line, v.rule)).collect();
    let mut sorted = keys.clone();
    sorted.sort();
    assert_eq!(keys, sorted);
}

#[test]
fn repository_tree_lints_clean() {
    let r = run(&repo_root()).expect("lint run on the repo tree");
    assert!(r.clean(), "the repo tree must lint clean:\n{}", r.render(true));
    let unused: Vec<_> = r.pragmas.iter().filter(|p| !p.used).collect();
    assert!(unused.is_empty(), "stale allow pragmas (delete them): {unused:?}");
    assert!(r.files_scanned > 40, "suspiciously few files scanned: {}", r.files_scanned);
}

#[test]
fn cli_exit_codes_and_json_artifact() {
    let bin = env!("CARGO_BIN_EXE_armor");

    let ok = Command::new(bin)
        .arg("lint")
        .arg("--root")
        .arg(fixture("clean"))
        .output()
        .expect("spawn armor lint");
    assert!(
        ok.status.success(),
        "clean fixture must exit 0:\n{}{}",
        String::from_utf8_lossy(&ok.stdout),
        String::from_utf8_lossy(&ok.stderr)
    );
    let stdout = String::from_utf8_lossy(&ok.stdout);
    assert!(stdout.contains("lint: clean"), "{stdout}");
    assert!(stdout.contains("2 pragma(s) honored"), "{stdout}");

    let json_path = std::env::temp_dir().join("armor_lint_self_report.json");
    let bad = Command::new(bin)
        .arg("lint")
        .arg("--fix-plan")
        .arg("--json")
        .arg(&json_path)
        .arg("--root")
        .arg(fixture("violations"))
        .output()
        .expect("spawn armor lint");
    assert!(!bad.status.success(), "violations fixture must exit non-zero");
    let stdout = String::from_utf8_lossy(&bad.stdout);
    assert!(stdout.contains(" · PANIC_UNWRAP · "), "{stdout}");
    assert!(stdout.contains(" · DRIFT_METRIC · "), "{stdout}");
    assert!(stdout.contains("fix: "), "--fix-plan must print remediations: {stdout}");

    let raw = std::fs::read_to_string(&json_path).expect("--json artifact written");
    let j = armor::util::json::Json::parse(&raw).expect("artifact parses");
    assert_eq!(j.get("clean").as_bool(), Some(false));
    let violations = j.get("violations").as_arr().expect("violations array");
    assert_eq!(violations.len(), 17);
    assert!(violations.iter().all(|v| {
        v.get("path").as_str().is_some()
            && v.get("line").as_usize().is_some()
            && v.get("rule").as_str().is_some()
            && v.get("message").as_str().is_some()
            && v.get("fix").as_str().is_some()
    }));
    std::fs::remove_file(&json_path).ok();
}
