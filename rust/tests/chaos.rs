//! Deterministic chaos tests for the serve plane.
//!
//! Each test arms a seeded [`FailPoints`] registry — injected KV-pool
//! allocation refusals (forcing spurious preemptions and admission
//! retries) and service-loop stalls — and drives mixed-priority traffic
//! through the engine and the service worker. The acceptance bar, under
//! every injected schedule:
//!
//! - **no panics** anywhere in the serve plane;
//! - **bit-identical outputs**: every request generates exactly the
//!   tokens of an uninjected run;
//! - **exact accounting**: pool reserved/allocated pages return to zero
//!   after drain, with no release underflows.
//!
//! The schedule is replayable: `ARMOR_FAILPOINT_SEED` (default 0) selects
//! it, `ARMOR_FAILPOINTS` (default below) sets the sites and
//! probabilities. CI runs this suite under two fixed seeds. Probabilities
//! of 1.0 for `kv_alloc` are excluded by construction — a reservation
//! that can *never* succeed would livelock the drain loop, which is a
//! misconfiguration rather than a fault schedule.

use armor::model::{CompiledModel, GptConfig, GptModel};
use armor::obs::FailPoints;
use armor::serve::{
    Engine, EngineConfig, EngineService, GenerateParams, KvPool, SchedPolicy, TokenEvent,
};
use armor::util::rng::Pcg64;
use std::sync::Arc;

fn small_model() -> CompiledModel {
    let cfg = GptConfig { d_model: 32, n_layers: 2, n_heads: 2, d_ff: 64, max_seq: 32, ..GptConfig::tiny() };
    let mut rng = Pcg64::seed_from_u64(0);
    CompiledModel::compile(&GptModel::random_init(&cfg, &mut rng), None).unwrap()
}

fn toks(n: usize, seed: u64) -> Vec<u16> {
    let mut rng = Pcg64::seed_from_u64(seed);
    (0..n).map(|_| rng.next_below(250) as u16).collect()
}

/// The injected schedule: seed from `ARMOR_FAILPOINT_SEED`, spec from
/// `ARMOR_FAILPOINTS`, with in-test defaults so a bare `cargo test` still
/// exercises the chaos paths.
fn chaos_failpoints() -> FailPoints {
    let spec = std::env::var("ARMOR_FAILPOINTS")
        .unwrap_or_else(|_| "kv_alloc:0.2,svc_channel_stall:0.05".to_string());
    let seed = std::env::var("ARMOR_FAILPOINT_SEED")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0u64);
    FailPoints::parse(&spec, seed).expect("chaos spec must parse")
}

/// Mixed-priority traffic: even requests urgent (lane 0), odd ones lane 3.
fn traffic() -> Vec<(Vec<u16>, usize, u8)> {
    (0..6)
        .map(|i| (toks(3 + i % 4, 7000 + i as u64), 4 + i % 5, if i % 2 == 0 { 0 } else { 3u8 }))
        .collect()
}

/// Engine under a tight budget plus injected allocation refusals: the
/// combined (real + injected) pressure forces evictions and retries, and
/// the drained outputs still match a clean engine bit for bit.
#[test]
fn chaos_engine_drain_is_bit_identical_and_flat() {
    let compiled = small_model();
    let probe = KvPool::new(&compiled.cfg, 4, None).unwrap();
    let worst = traffic()
        .iter()
        .map(|(p, n, _)| probe.pages_for_seq((p.len() + n - 1).min(compiled.cfg.max_seq)))
        .max()
        .unwrap();
    let run = |fp: Option<FailPoints>| {
        let mut engine = Engine::new(
            compiled.clone(),
            EngineConfig {
                max_batch: 3,
                page_positions: 4,
                kv_budget_bytes: Some(2 * worst * probe.page_bytes()),
                prefix_sharing: false,
                policy: SchedPolicy::Priority,
                ..EngineConfig::default()
            },
        )
        .unwrap();
        // arm the injected schedule — or explicitly disarm the baseline,
        // so an exported ARMOR_FAILPOINTS can never pollute the reference
        engine.set_failpoints(fp);
        let ids: Vec<_> =
            traffic().iter().map(|(p, n, pr)| engine.submit_with(p, *n, *pr, None)).collect();
        let report = engine.drain();
        assert_eq!(engine.pool().pages_reserved(), 0, "reservation accounting must stay exact");
        assert_eq!(engine.pool().pages_allocated(), 0, "no page may leak under injected faults");
        assert_eq!(engine.pool().release_underflows(), 0);
        assert_eq!(report.aborts_timeout + report.aborts_disconnect, 0, "no abort knobs armed");
        let outputs: Vec<Vec<u16>> = ids
            .iter()
            .map(|id| {
                report
                    .requests
                    .iter()
                    .find(|r| r.id == *id)
                    .expect("every request completes")
                    .generated
                    .clone()
            })
            .collect();
        outputs
    };
    let faulty = run(Some(chaos_failpoints()));
    let clean = run(None);
    assert_eq!(faulty, clean, "injected refusals changed an output");
}

/// The full service plane — worker thread, command channel, streaming
/// receivers — under both injected sites at once. Survivor streams match
/// the clean engine, events stay ordered with exactly one terminal event,
/// and the drain report covers every request.
#[test]
fn chaos_service_streams_survive_injected_faults() {
    let compiled = small_model();
    // clean reference continuations, one solo run per request
    let expect: Vec<Vec<u16>> = traffic()
        .iter()
        .map(|(p, n, _)| compiled.generate(p, *n)[p.len()..].to_vec())
        .collect();
    let mut engine = Engine::new(
        compiled.clone(),
        EngineConfig { max_batch: 3, policy: SchedPolicy::Priority, ..EngineConfig::default() },
    )
    .unwrap();
    engine.set_failpoints(Some(chaos_failpoints()));
    let service = Arc::new(EngineService::spawn(engine).unwrap());
    let handles: Vec<_> = traffic()
        .into_iter()
        .map(|(prompt, max_new, priority)| {
            let svc = Arc::clone(&service);
            std::thread::spawn(move || {
                let (_, rx) = svc
                    .generate(GenerateParams { prompt, max_new, priority, deadline: None })
                    .expect("no queue bound armed");
                let mut got = Vec::new();
                for ev in rx.iter() {
                    match ev {
                        TokenEvent::Token { index, token } => {
                            assert_eq!(index, got.len(), "events out of order under chaos");
                            got.push(token);
                        }
                        TokenEvent::Done(stats) => {
                            assert_eq!(stats.generated, got);
                            return got;
                        }
                        TokenEvent::Aborted(stats) => {
                            panic!("spurious abort under chaos: {stats:?}")
                        }
                    }
                }
                panic!("stream ended without a terminal event");
            })
        })
        .collect();
    let mut streamed: Vec<Vec<u16>> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    streamed.sort();
    let mut expect = expect;
    expect.sort();
    assert_eq!(streamed, expect, "a chaos schedule changed a streamed continuation");
    let report = service.shutdown().expect("drain report");
    assert_eq!(report.requests.len(), 6);
    assert_eq!(report.aborts_timeout + report.aborts_disconnect, 0);
}
