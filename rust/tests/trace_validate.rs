//! Trace-timeline validation: every Chrome trace the serve engine emits —
//! in-process here, or a file produced by `armor serve --trace` when CI
//! points `ARMOR_TRACE_FILE` at one — must load as trace-event JSON and
//! pass the structural checks in `armor::obs::validate_trace` (known
//! phases, finite monotonic timestamps per (pid, tid), balanced B/E
//! stacks, non-negative span durations).

use armor::model::{CompiledModel, GptConfig, GptModel};
use armor::obs::{validate_trace, TraceRecorder};
use armor::serve::{Engine, EngineConfig};
use armor::util::rng::Pcg64;

fn tiny_engine() -> Engine {
    let cfg = GptConfig { d_model: 32, n_layers: 2, n_heads: 2, d_ff: 64, max_seq: 48, ..GptConfig::tiny() };
    let mut rng = Pcg64::seed_from_u64(11);
    let model = GptModel::random_init(&cfg, &mut rng);
    let compiled = CompiledModel::compile(&model, None).unwrap();
    Engine::new(compiled, EngineConfig { max_batch: 3, ..EngineConfig::default() })
        .expect("tiny engine config")
}

/// A traced drain over real traffic produces a loadable, well-formed
/// timeline containing the step spans and their nested phases.
#[test]
fn traced_serve_drain_validates() {
    let mut engine = tiny_engine();
    let trace = TraceRecorder::new();
    engine.set_trace(trace.clone());
    let mut rng = Pcg64::seed_from_u64(12);
    for _ in 0..4 {
        let prompt: Vec<u16> = (0..10).map(|_| rng.next_below(256) as u16).collect();
        engine.submit(&prompt, 6);
    }
    let report = engine.drain();
    assert_eq!(report.requests.len(), 4);

    let text = trace.to_json().to_string_compact();
    let summary = validate_trace(&text).expect("engine trace is structurally valid");
    assert!(summary.spans > 0, "traced drain recorded no spans");
    for needle in ["\"step\"", "\"prefill\"", "\"decode\"", "\"attention\"", "\"retire\""] {
        assert!(text.contains(needle), "trace missing {needle} events");
    }
}

/// A zero-request drain must still write a valid (empty) timeline — the
/// `--trace` flag cannot depend on traffic having arrived.
#[test]
fn empty_drain_trace_validates() {
    let mut engine = tiny_engine();
    let trace = TraceRecorder::new();
    engine.set_trace(trace.clone());
    let report = engine.drain();
    assert!(report.requests.is_empty());
    let summary =
        validate_trace(&trace.to_json().to_string_compact()).expect("empty trace is valid");
    assert_eq!(summary.events, 0);
}

/// CI hook: when `ARMOR_TRACE_FILE` names a trace written by
/// `armor serve --trace`, validate that exact artifact. Skips (with a
/// notice) when the variable is unset so plain `cargo test` is unaffected.
#[test]
fn trace_file_from_env_validates() {
    let Ok(path) = std::env::var("ARMOR_TRACE_FILE") else {
        eprintln!("[skip] ARMOR_TRACE_FILE not set — nothing to validate");
        return;
    };
    let text = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("reading ARMOR_TRACE_FILE {path}: {e}"));
    let summary = validate_trace(&text).expect("serve --trace artifact is structurally valid");
    assert!(summary.events > 0, "serve --trace artifact {path} recorded no events");
    eprintln!(
        "[trace] {path}: {} events ({} spans, {} instants, {} counter samples) valid",
        summary.events, summary.spans, summary.instants, summary.counters
    );
}
