//! Loopback integration tests for the HTTP/1.1 serving front-end.
//!
//! Everything runs against a real `HttpServer` bound to an ephemeral
//! loopback port — the same listener/parser/handler path `armor serve
//! --listen` uses — with `armor::serve::http::client` on the other end of
//! the socket. Covers the `API.md` acceptance list: streamed tokens are
//! bit-identical to a direct `Engine` run, `/metrics` and `/v1/stats` stay
//! valid mid-stream, malformed requests get structured 4xx envelopes,
//! keep-alive serves sequential requests, and graceful shutdown drains
//! in-flight streams to a clean chunked termination while refusing new
//! work with `503`.

use armor::model::{CompiledModel, GptConfig, GptModel};
use armor::serve::http::{client, HttpServer, MAX_BODY_BYTES};
use armor::serve::{Engine, EngineConfig, EngineService};
use armor::util::json::Json;
use armor::util::rng::Pcg64;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::{mpsc, Arc};
use std::time::Duration;

fn small_model() -> CompiledModel {
    let cfg = GptConfig {
        d_model: 32,
        n_layers: 2,
        n_heads: 2,
        d_ff: 64,
        max_seq: 64,
        ..GptConfig::tiny()
    };
    let mut rng = Pcg64::seed_from_u64(0);
    CompiledModel::compile(&GptModel::random_init(&cfg, &mut rng), None).unwrap()
}

fn toks(n: usize, seed: u64) -> Vec<u16> {
    let mut rng = Pcg64::seed_from_u64(seed);
    (0..n).map(|_| rng.next_below(256) as u16).collect()
}

fn serve(compiled: CompiledModel, cfg: EngineConfig) -> (HttpServer, SocketAddr) {
    let service = Arc::new(EngineService::spawn(Engine::new(compiled, cfg).unwrap()).unwrap());
    let server = HttpServer::bind(service, "127.0.0.1:0").unwrap();
    let addr = server.local_addr();
    (server, addr)
}

fn gen_body(prompt: &[u16], max_new: usize) -> String {
    let toks: Vec<String> = prompt.iter().map(|t| t.to_string()).collect();
    format!(r#"{{"prompt":[{}],"max_new":{max_new}}}"#, toks.join(","))
}

/// Extract the generated token values from a streamed response, asserting
/// index order and that the terminal event agrees.
fn streamed_tokens(resp: &client::HttpResponse) -> Vec<u16> {
    assert_eq!(resp.status, 200);
    assert_eq!(resp.header("content-type"), Some("application/x-ndjson"));
    let mut got: Vec<u16> = Vec::new();
    let mut done = false;
    for chunk in &resp.chunks {
        let ev = Json::parse(std::str::from_utf8(chunk).unwrap().trim()).expect("event is JSON");
        if ev.get("done").as_bool() == Some(true) {
            assert_eq!(
                ev.get("stats").get("n_generated").as_usize(),
                Some(got.len()),
                "terminal stats disagree with the streamed event count"
            );
            done = true;
        } else {
            assert_eq!(ev.get("index").as_usize(), Some(got.len()), "events out of order");
            got.push(ev.get("token").as_usize().unwrap() as u16);
        }
    }
    assert!(done, "stream ended without a terminal done event");
    got
}

/// One request over an already-open keep-alive connection; reads exactly
/// one `Content-Length`-framed response and leaves the stream usable.
fn keepalive_roundtrip(stream: &mut TcpStream, head: &str) -> (u16, String) {
    stream.write_all(head.as_bytes()).unwrap();
    let mut buf = Vec::new();
    let head_end = loop {
        if let Some(i) = buf.windows(4).position(|w| w == b"\r\n\r\n") {
            break i;
        }
        let mut chunk = [0u8; 1024];
        let n = stream.read(&mut chunk).unwrap();
        assert!(n > 0, "connection closed before response head");
        buf.extend_from_slice(&chunk[..n]);
    };
    let head_text = String::from_utf8_lossy(&buf[..head_end]).into_owned();
    let status: u16 =
        head_text.split(' ').nth(1).and_then(|s| s.parse().ok()).expect("status line");
    let need: usize = head_text
        .lines()
        .find_map(|l| l.to_ascii_lowercase().strip_prefix("content-length:").map(str::to_string))
        .and_then(|v| v.trim().parse().ok())
        .expect("keep-alive responses are Content-Length framed");
    let mut pos = head_end + 4;
    while buf.len() < pos + need {
        let mut chunk = [0u8; 1024];
        let n = stream.read(&mut chunk).unwrap();
        assert!(n > 0, "connection closed mid-body");
        buf.extend_from_slice(&chunk[..n]);
    }
    pos += need;
    (status, String::from_utf8_lossy(&buf[pos - need..pos]).into_owned())
}

/// Concurrent streams over real sockets produce exactly the tokens a
/// direct single-threaded engine run produces.
#[test]
fn streamed_tokens_match_direct_engine() {
    let compiled = small_model();
    let cfg = EngineConfig { max_batch: 3, ..EngineConfig::default() };
    let prompts: Vec<Vec<u16>> = (0..4).map(|i| toks(4 + i, 900 + i as u64)).collect();
    let max_new = [6usize, 3, 8, 5];

    let mut direct = Engine::new(compiled.clone(), cfg).unwrap();
    for (p, &n) in prompts.iter().zip(&max_new) {
        direct.submit(p, n);
    }
    let mut expect: Vec<Vec<u16>> =
        direct.drain().requests.iter().map(|r| r.generated.clone()).collect();
    expect.sort();

    let (server, addr) = serve(compiled, cfg);
    let handles: Vec<_> = prompts
        .iter()
        .zip(&max_new)
        .map(|(p, &n)| {
            let body = gen_body(p, n);
            std::thread::spawn(move || {
                let resp = client::post_stream(addr, "/v1/generate", &body, |_| {}).unwrap();
                assert!(resp.header("x-request-id").is_some());
                streamed_tokens(&resp)
            })
        })
        .collect();
    let mut streamed: Vec<Vec<u16>> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    streamed.sort();
    assert_eq!(streamed, expect, "wire streams diverged from the direct engine");

    let report = server.shutdown().expect("shutdown returns the session report");
    assert_eq!(report.requests.len(), 4);
    assert_eq!(report.generated_tokens, max_new.iter().sum::<usize>());
}

/// `/metrics` and `/v1/stats` answer from other connections while a
/// generate stream is mid-flight, and both payloads stay well-formed.
#[test]
fn metrics_and_stats_are_live_mid_stream() {
    let (server, addr) = serve(small_model(), EngineConfig::default());
    let (probe_tx, probe_rx) = mpsc::channel();
    let mut probed = false;
    let resp = client::post_stream(addr, "/v1/generate", &gen_body(&toks(4, 42), 24), |_| {
        // first streamed token: the request is provably mid-flight — hit
        // the observability routes on fresh connections right now
        if !probed {
            probed = true;
            let metrics = client::get(addr, "/metrics").unwrap();
            let stats = client::get(addr, "/v1/stats").unwrap();
            probe_tx.send((metrics, stats)).unwrap();
        }
    })
    .unwrap();
    let tokens = streamed_tokens(&resp);
    assert_eq!(tokens.len(), 24);

    let (metrics, stats) = probe_rx.recv().unwrap();
    assert_eq!(metrics.status, 200);
    assert_eq!(metrics.header("content-type"), Some("text/plain; version=0.0.4"));
    let text = metrics.body_text();
    assert!(text.lines().any(|l| l.starts_with("# TYPE armor_requests_total counter")));
    assert!(
        text.lines().all(|l| l.is_empty() || l.starts_with('#') || l.starts_with("armor_")),
        "exposition has non-comment, non-sample lines"
    );
    assert_eq!(stats.status, 200);
    let v = Json::parse(&stats.body_text()).expect("mid-stream stats body is JSON");
    assert_eq!(v.get("draining").as_bool(), Some(false));
    assert!(v.get("last_window").as_obj().is_some());

    // after the stream retires, totals catch up on the same registry
    let after = Json::parse(&client::get(addr, "/v1/stats").unwrap().body_text()).unwrap();
    assert_eq!(after.get("requests").as_usize(), Some(1));
    assert_eq!(after.get("generated_tokens").as_usize(), Some(24));
    server.shutdown();
}

/// Malformed requests get the structured error envelope with the right
/// status: 400 (bad body), 404, 405 (+Allow), 413, and a garbage request
/// line.
#[test]
fn malformed_requests_get_structured_errors() {
    let (server, addr) = serve(small_model(), EngineConfig::default());
    let envelope = |resp: &client::HttpResponse, code: usize, reason: &str| {
        let v = Json::parse(&resp.body_text()).expect("error body is the JSON envelope");
        assert_eq!(v.get("error").get("code").as_usize(), Some(code));
        assert_eq!(v.get("error").get("reason").as_str(), Some(reason));
        assert!(!v.get("error").get("message").as_str().unwrap().is_empty());
    };

    let resp = client::post(addr, "/v1/generate", r#"{"max_new":4}"#).unwrap();
    assert_eq!(resp.status, 400);
    envelope(&resp, 400, "bad_request");

    let resp = client::get(addr, "/v1/nope").unwrap();
    assert_eq!(resp.status, 404);
    envelope(&resp, 404, "not_found");

    let resp = client::post(addr, "/healthz", "{}").unwrap();
    assert_eq!(resp.status, 405);
    assert_eq!(resp.header("allow"), Some("GET"));
    envelope(&resp, 405, "method_not_allowed");

    // an oversized declared body is refused from the headers alone
    let mut stream = TcpStream::connect(addr).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let head = format!(
        "POST /v1/generate HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n",
        MAX_BODY_BYTES + 1
    );
    stream.write_all(head.as_bytes()).unwrap();
    let mut raw = String::new();
    stream.read_to_string(&mut raw).unwrap();
    assert!(raw.starts_with("HTTP/1.1 413 "), "got: {raw:?}");
    assert!(raw.contains("payload_too_large"));
    assert!(raw.contains("Connection: close"));

    // a garbage request line is a 400 and the connection closes
    let mut stream = TcpStream::connect(addr).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    stream.write_all(b"GARBAGE\r\n\r\n").unwrap();
    let mut raw = String::new();
    stream.read_to_string(&mut raw).unwrap();
    assert!(raw.starts_with("HTTP/1.1 400 "), "got: {raw:?}");
    assert!(raw.contains("bad_request"));
    server.shutdown();
}

/// One keep-alive connection serves sequential requests; responses are
/// framed so the next request parses cleanly.
#[test]
fn keep_alive_serves_sequential_requests() {
    let (server, addr) = serve(small_model(), EngineConfig::default());
    let mut stream = TcpStream::connect(addr).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(10))).unwrap();

    let (status, body) = keepalive_roundtrip(&mut stream, "GET /healthz HTTP/1.1\r\nHost: t\r\n\r\n");
    assert_eq!(status, 200);
    assert!(body.contains("\"ok\""));

    let (status, body) = keepalive_roundtrip(&mut stream, "GET /v1/stats HTTP/1.1\r\nHost: t\r\n\r\n");
    assert_eq!(status, 200);
    assert!(Json::parse(&body).is_ok());

    // a 404 keeps the connection alive too — framing survives errors
    let (status, _) = keepalive_roundtrip(&mut stream, "GET /missing HTTP/1.1\r\nHost: t\r\n\r\n");
    assert_eq!(status, 404);

    let (status, body) = keepalive_roundtrip(&mut stream, "GET /healthz HTTP/1.1\r\nHost: t\r\n\r\n");
    assert_eq!(status, 200);
    assert!(body.contains("\"ok\""));
    server.shutdown();
}

/// A bounded queue surfaces overload over the wire: with `max_batch 1`
/// and `max_queue 1`, the third concurrent generate gets the structured
/// `429` envelope with a `Retry-After` header, while the two admitted
/// streams run to completion untouched. The always-firing service-stall
/// failpoint paces the worker to ~2 ms/step so request A provably
/// outlives the poll-then-reject sequence below — the tiny model would
/// otherwise drain in microseconds and race the rejection.
#[test]
fn overload_returns_429_with_retry_after() {
    use armor::obs::FailPoints;
    let mut engine = Engine::new(
        small_model(),
        EngineConfig { max_batch: 1, max_queue: Some(1), ..EngineConfig::default() },
    )
    .unwrap();
    engine.set_failpoints(Some(FailPoints::parse("svc_channel_stall:1", 3).unwrap()));
    let server =
        HttpServer::bind(Arc::new(EngineService::spawn(engine).unwrap()), "127.0.0.1:0")
            .unwrap();
    let addr = server.local_addr();

    // A occupies the single batch slot; wait for its first token so the
    // admission is provable before B is submitted.
    let (first_tx, first_rx) = mpsc::channel();
    let a = std::thread::spawn(move || {
        let mut sent = false;
        let resp = client::post_stream(addr, "/v1/generate", &gen_body(&toks(4, 11), 24), |_| {
            if !sent {
                sent = true;
                first_tx.send(()).unwrap();
            }
        })
        .unwrap();
        streamed_tokens(&resp).len()
    });
    first_rx.recv().unwrap();

    // B fills the one queue slot; poll /v1/stats until the worker has
    // absorbed it so the rejection below is deterministic.
    let b = std::thread::spawn(move || {
        let resp = client::post_stream(addr, "/v1/generate", &gen_body(&toks(5, 12), 3), |_| {}).unwrap();
        streamed_tokens(&resp).len()
    });
    let deadline = std::time::Instant::now() + Duration::from_secs(10);
    loop {
        let v = Json::parse(&client::get(addr, "/v1/stats").unwrap().body_text()).unwrap();
        if v.get("queue_depth").as_usize() == Some(1) {
            break;
        }
        assert!(std::time::Instant::now() < deadline, "queued request never became visible");
        std::thread::sleep(Duration::from_millis(2));
    }

    let resp = client::post(addr, "/v1/generate", &gen_body(&toks(3, 13), 2)).unwrap();
    assert_eq!(resp.status, 429);
    let retry: u64 = resp
        .header("retry-after")
        .expect("429 carries Retry-After")
        .parse()
        .expect("Retry-After is integral seconds");
    assert!(retry >= 1);
    let v = Json::parse(&resp.body_text()).expect("429 body is the JSON envelope");
    assert_eq!(v.get("error").get("code").as_usize(), Some(429));
    assert_eq!(v.get("error").get("reason").as_str(), Some("overloaded"));
    assert!(v.get("error").get("message").as_str().unwrap().contains("queue full"));

    assert_eq!(a.join().unwrap(), 24, "admitted stream A must be untouched by the rejection");
    assert_eq!(b.join().unwrap(), 3, "queued stream B must still complete");
    let report = server.shutdown().expect("shutdown returns the session report");
    assert_eq!(report.requests.len(), 2);
    assert_eq!(report.rejections_429, 1);
}

/// Graceful shutdown mid-stream: the in-flight stream runs to a clean
/// chunked termination, while an already-open connection deterministically
/// sees `503` on `/healthz` and on new generate submissions.
#[test]
fn graceful_shutdown_drains_in_flight_streams() {
    let (server, addr) = serve(small_model(), EngineConfig::default());

    // an existing keep-alive connection, opened while still serving
    let mut probe = TcpStream::connect(addr).unwrap();
    probe.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let (status, _) = keepalive_roundtrip(&mut probe, "GET /healthz HTTP/1.1\r\nHost: t\r\n\r\n");
    assert_eq!(status, 200);

    let (first_tx, first_rx) = mpsc::channel();
    let streamer = std::thread::spawn(move || {
        let mut sent = false;
        let resp = client::post_stream(addr, "/v1/generate", &gen_body(&toks(4, 7), 32), |_| {
            if !sent {
                sent = true;
                first_tx.send(()).unwrap();
            }
        })
        .unwrap();
        streamed_tokens(&resp)
    });
    first_rx.recv().unwrap(); // the stream is provably mid-flight
    server.begin_shutdown();

    // the pre-existing connection keeps working and reports draining
    let (status, body) = keepalive_roundtrip(&mut probe, "GET /healthz HTTP/1.1\r\nHost: t\r\n\r\n");
    assert_eq!(status, 503);
    assert!(body.contains("draining"));
    let gen = gen_body(&[1, 2, 3], 4);
    let head = format!(
        "POST /v1/generate HTTP/1.1\r\nHost: t\r\nContent-Type: application/json\r\nContent-Length: {}\r\n\r\n{gen}",
        gen.len()
    );
    let (status, body) = keepalive_roundtrip(&mut probe, &head);
    assert_eq!(status, 503, "draining must refuse new generates");
    assert!(body.contains("\"draining\""));

    // the in-flight stream still terminates cleanly with all its tokens
    let report = server.shutdown().expect("shutdown returns the session report");
    let tokens = streamer.join().unwrap();
    assert_eq!(tokens.len(), 32);
    assert_eq!(report.requests.len(), 1, "only the in-flight request completed");
    assert_eq!(report.generated_tokens, 32);
    assert!(server.shutdown().is_none(), "second shutdown is a no-op");
}
