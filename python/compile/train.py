"""Build-time training of the tiny GPT on the synthetic corpus.

Runs ONCE during `make artifacts` (never at serving/pruning time). The
trained weights are exported as a `.tsr` bundle that the Rust runtime loads
natively. Training data comes from `artifacts/corpus/train.txt`, generated
by `armor gen-corpus` so Python and Rust see the same bytes.
"""

from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import model as M
from .tsr import save_tsr


def load_corpus(path: str) -> np.ndarray:
    with open(path, "rb") as f:
        data = f.read()
    return np.frombuffer(data, dtype=np.uint8).astype(np.int32)


def make_batches(tokens: np.ndarray, batch: int, seq: int, n_steps: int, seed: int):
    rng = np.random.default_rng(seed)
    starts_max = len(tokens) - seq - 1
    for _ in range(n_steps):
        starts = rng.integers(0, starts_max, size=batch)
        yield np.stack([tokens[s : s + seq] for s in starts])


def train(
    cfg: dict,
    corpus_path: str,
    out_path: str,
    *,
    steps: int = 250,
    batch: int = 8,
    lr: float = 3e-3,
    seed: int = 0,
    log_every: int = 25,
) -> dict:
    """Train and export; returns summary metrics."""
    tokens = load_corpus(corpus_path)
    seq = cfg["max_seq"]
    params = M.init_params(cfg, jax.random.PRNGKey(seed))
    names = sorted(params)

    loss_grad = jax.jit(jax.value_and_grad(lambda p, t: M.batch_loss(p, cfg, t)))

    # plain Adam
    m = {k: jnp.zeros_like(v) for k, v in params.items()}
    v = {k: jnp.zeros_like(v_) for k, v_ in params.items()}
    b1, b2, eps = 0.9, 0.999, 1e-8

    history = []
    t_start = time.time()
    for step, tb in enumerate(make_batches(tokens, batch, seq, steps, seed + 1), start=1):
        loss, grads = loss_grad(params, jnp.asarray(tb))
        for k in names:
            g = grads[k]
            m[k] = b1 * m[k] + (1 - b1) * g
            v[k] = b2 * v[k] + (1 - b2) * g * g
            mhat = m[k] / (1 - b1**step)
            vhat = v[k] / (1 - b2**step)
            params[k] = params[k] - lr * mhat / (jnp.sqrt(vhat) + eps)
        if step % log_every == 0 or step == 1:
            history.append({"step": step, "loss": float(loss)})
            print(f"[train] step {step:5d}  loss {float(loss):.4f}  "
                  f"({time.time() - t_start:.0f}s)", flush=True)

    # held-out NLL for the Rust cross-check
    rng = np.random.default_rng(seed + 2)
    starts = rng.integers(0, len(tokens) - seq - 1, size=8)
    eval_batch = jnp.asarray(np.stack([tokens[s : s + seq] for s in starts]))
    eval_nll = float(M.batch_loss(params, cfg, eval_batch))

    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    tensors = {k: np.asarray(val) for k, val in params.items()}
    meta = {
        "config": cfg,
        "train_steps": steps,
        "final_train_loss": history[-1]["loss"] if history else None,
        "eval_nll": eval_nll,
        "history": history,
    }
    save_tsr(out_path, tensors, meta)
    print(f"[train] saved {out_path}  eval_nll={eval_nll:.4f}")
    return meta


def main():
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--config", default="../configs/tiny.json")
    ap.add_argument("--corpus", default="../artifacts/corpus/train.txt")
    ap.add_argument("--out", default="../artifacts/model/tiny.tsr")
    ap.add_argument("--steps", type=int, default=int(os.environ.get("ARMOR_TRAIN_STEPS", 250)))
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-3)
    args = ap.parse_args()
    with open(args.config) as f:
        cfg = json.load(f)
    train(cfg, args.corpus, args.out, steps=args.steps, batch=args.batch, lr=args.lr)


if __name__ == "__main__":
    main()
