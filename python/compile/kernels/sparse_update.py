"""Layer-1 Pallas kernel: the batched sparse-group least-squares sweep —
the algorithmic hot spot of the ARMOR sparse-core update (paper Eq. 7–9,
Appendix B.1).

One grid step = one (i, j) block's selected group: load the block residual
`E`, the wrapper column `a`, the M touched B-rows `u`, the activation
weights `d`, and the current group values; form the M×M weighted Gram and
the M-vector of weighted correlations; solve the 2-variable closed form for
every C(M, 2) candidate mask; emit per-candidate gains and values. The
host-side driver (Rust, or `ref.group_ls_ref` in tests) takes the argmax.

The 2×2 solve is branch-free via the adjugate with a damped determinant —
the Pallas-friendly equivalent of `linalg::solve_sym2x2_pinv`.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(combos: tuple[tuple[int, int], ...], e_ref, a_ref, u_ref, d_ref, s_ref,
            gains_ref, vals_ref):
    e = e_ref[0]  # (db, db)
    a = a_ref[0]  # (db,)
    u = u_ref[0]  # (m, db)
    d = d_ref[0]  # (db,)
    cur = s_ref[0]  # (m,)

    a_sq = jnp.sum(a * a)
    v = e.T @ a + a_sq * (cur @ u)  # (db,)
    g_full = jnp.einsum("td,d,ud->tu", u, d, u)  # (m, m)
    r_full = u @ (d * v)  # (m,)

    for c, (i1, i2) in enumerate(combos):
        g00 = g_full[i1, i1]
        g01 = g_full[i1, i2]
        g11 = g_full[i2, i2]
        r0 = r_full[i1]
        r1 = r_full[i2]
        scale = jnp.maximum(jnp.maximum(jnp.abs(g00), jnp.abs(g11)), 1e-30)
        det = g00 * g11 - g01 * g01
        ok = det > 1e-10 * scale * scale
        inv_det = jnp.where(ok, 1.0 / jnp.where(ok, det, 1.0), 0.0)
        w0 = (g11 * r0 - g01 * r1) * inv_det
        w1 = (g00 * r1 - g01 * r0) * inv_det
        # degenerate fallback: diagonal solve (covers rank-1 G approximately)
        w0 = jnp.where(ok, w0, jnp.where(g00 > 1e-30 * scale, r0 / jnp.maximum(g00, 1e-30), 0.0))
        w1 = jnp.where(ok, w1, 0.0)
        denom = jnp.where(a_sq > 1e-30, a_sq, 1.0)
        gain = jnp.where(a_sq > 1e-30, (r0 * w0 + r1 * w1) / denom, 0.0)
        gains_ref[0, c] = gain
        vals_ref[0, c, 0] = jnp.where(a_sq > 1e-30, w0 / denom, 0.0)
        vals_ref[0, c, 1] = jnp.where(a_sq > 1e-30, w1 / denom, 0.0)


def sparse_group_ls(e, a_cols, u_rows, d, cur_vals, m: int = 4):
    """Batched mask sweep over `nb` selected groups.

    e:        (nb, db, db) block residuals
    a_cols:   (nb, db)     wrapper columns
    u_rows:   (nb, m, db)  touched B rows
    d:        (nb, db)     activation weights
    cur_vals: (nb, m)      current group values
    Returns (gains (nb, C), vals (nb, C, 2)) for the C = C(m,2) masks in
    lexicographic order.
    """
    nb, db, _ = e.shape
    combos = tuple((i, j) for i in range(m) for j in range(i + 1, m))
    ncomb = len(combos)
    f32 = jnp.float32
    return pl.pallas_call(
        functools.partial(_kernel, combos),
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1, db, db), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, db), lambda i: (i, 0)),
            pl.BlockSpec((1, m, db), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, db), lambda i: (i, 0)),
            pl.BlockSpec((1, m), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, ncomb), lambda i: (i, 0)),
            pl.BlockSpec((1, ncomb, 2), lambda i: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb, ncomb), f32),
            jax.ShapeDtypeStruct((nb, ncomb, 2), f32),
        ],
        interpret=True,
    )(e.astype(f32), a_cols.astype(f32), u_rows.astype(f32), d.astype(f32), cur_vals.astype(f32))
