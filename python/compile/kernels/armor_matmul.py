"""Layer-1 Pallas kernel: fused ARMOR reconstruction `Ŵ = A · core · B`.

The grid iterates over (block-row i, block-col j); each step streams one
`db × db` core tile plus the two matching wrapper blocks HBM→VMEM and runs
two `db × db` MXU matmuls — the TPU analog of the paper's per-threadblock
tiling (DESIGN.md §Hardware-Adaptation). With db ≤ 128 each operand fits a
single MXU tile.

Lowered with `interpret=True`: the CPU PJRT plugin cannot run Mosaic
custom-calls; correctness is asserted against `ref.armor_matmul_ref`.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(a_ref, s_ref, b_ref, o_ref):
    a = a_ref[0]  # (db, db) wrapper block A_i
    s = s_ref[...]  # (db, db) core tile
    b = b_ref[0]  # (db, db) wrapper block B_j
    o_ref[...] = a @ s @ b


def armor_matmul(a_blocks: jax.Array, core: jax.Array, b_blocks: jax.Array) -> jax.Array:
    """`A · core · B` with block-diagonal A, B given as stacked blocks.

    a_blocks: (nbo, db, db); core: (d_out, d_in); b_blocks: (nbi, db, db).
    """
    nbo, db, _ = a_blocks.shape
    nbi = b_blocks.shape[0]
    d_out, d_in = core.shape
    assert d_out == nbo * db and d_in == nbi * db, (core.shape, a_blocks.shape, b_blocks.shape)
    return pl.pallas_call(
        _kernel,
        grid=(nbo, nbi),
        in_specs=[
            pl.BlockSpec((1, db, db), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((db, db), lambda i, j: (i, j)),
            pl.BlockSpec((1, db, db), lambda i, j: (j, 0, 0)),
        ],
        out_specs=pl.BlockSpec((db, db), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((d_out, d_in), jnp.float32),
        interpret=True,
    )(a_blocks.astype(jnp.float32), core.astype(jnp.float32), b_blocks.astype(jnp.float32))


def masked_armor_matmul(a_blocks, w_prime, mask, b_blocks):
    """Convenience wrapper applying the binary mask before reconstruction
    (the `W' ⊙ M` of paper Eq. 1), fused into the same lowered HLO."""
    return armor_matmul(a_blocks, w_prime * mask, b_blocks)
