"""Layer-1 Pallas kernels: ragged-batch decode attention over KV panels —
contiguous (`attn_decode`) and paged (`attn_decode_paged`).

TPU twins of the Rust serve path's blocked attention kernel
(`rust/src/model/attention.rs`), mirroring its blocking scheme:

- **Work decomposition**: the grid iterates over `(batch, head)` — exactly
  the Rust kernel's one-task-per-(sequence, head) split. Each step owns one
  query head-slice and that head's K/V storage in VMEM, the head-major
  layout `serve::KvCache` stores natively.
- **Raggedness**: sequences in the batch have mixed lengths; `seq_lens[b]`
  masks positions `>= len` to `-inf` before the softmax, the vectorized
  equivalent of the Rust kernel slicing its panel (or page-run chain) at
  `n_ctx`.
- **Paging** (`attn_decode_paged`): K/V live in a shared page *pool*
  (`serve::KvPool`'s layout — fixed-size pages of positions, refcount-shared
  prompt prefixes); each sequence names its chain through an int32 page
  table. The kernel gathers the chain, flattens it into the virtual panel,
  and masks the ragged tail — the vectorized mirror of the Rust kernel
  streaming `panel_runs` and carrying its position cursor across page
  boundaries. Two sequences whose tables point at the same pool pages share
  them in memory exactly like two forked Rust chains.
- **Softmax**: the same two-pass max/exp/normalize the Rust kernel runs —
  no online rescaling, so both twins agree with the scalar reference to
  f32 rounding.
- **Quantized pages** (`attn_decode_paged_q8`): the pool stores int8 K/V
  codes with one f32 scale per (page, head, position) slot — the layout
  `serve::KvPool` uses under `--quant q8-kv`, where each appended head
  slice is quantized once and its scale never rewritten. The kernel
  dequantizes after the gather, in VMEM (`codes · scale[..., None]`), the
  vectorized mirror of the Rust kernel folding the K scale into each row's
  score and the V scale into its softmax weight.

Lowered with `interpret=True`: the CPU PJRT plugin cannot run Mosaic
custom-calls; correctness is asserted against `ref.attn_decode_ref`. A
production Mosaic lowering of the paged variant would hoist the page table
into SMEM via `PrefetchScalarGridSpec` and DMA pages HBM→VMEM per grid
step instead of gathering a resident pool.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(q_ref, k_ref, v_ref, len_ref, o_ref, *, scale):
    q = q_ref[0, 0]  # (head_dim,) query slice of this (batch, head) task
    k = k_ref[0, 0]  # (max_seq, head_dim) K panel
    v = v_ref[0, 0]  # (max_seq, head_dim) V panel
    n = len_ref[0]  # this sequence's cached length
    # pass 1: scores over the panel, masked past the ragged length
    idx = jax.lax.broadcasted_iota(jnp.int32, (k.shape[0], 1), 0)[:, 0]
    scores = jnp.where(idx < n, (k @ q) * scale, -jnp.inf)
    # pass 2: two-pass softmax (max, then exp/normalize), as in the Rust twin
    m = jnp.max(scores)
    e = jnp.where(idx < n, jnp.exp(scores - m), 0.0)
    # pass 3: weighted V-sum
    o_ref[0, 0] = (e / jnp.sum(e)) @ v


def attn_decode(q: jax.Array, k: jax.Array, v: jax.Array, seq_lens: jax.Array) -> jax.Array:
    """Ragged batched decode attention.

    q:        (batch, n_heads, head_dim)  one query token per sequence
    k, v:     (batch, n_heads, max_seq, head_dim)  head-major KV panels
    seq_lens: (batch,) int32  cached positions per sequence (1..max_seq)

    Returns (batch, n_heads, head_dim) context rows.
    """
    bsz, n_heads, head_dim = q.shape
    assert k.shape == v.shape == (bsz, n_heads, k.shape[2], head_dim), (q.shape, k.shape, v.shape)
    assert seq_lens.shape == (bsz,), seq_lens.shape
    max_seq = k.shape[2]
    scale = 1.0 / float(head_dim) ** 0.5
    return pl.pallas_call(
        functools.partial(_kernel, scale=scale),
        grid=(bsz, n_heads),
        in_specs=[
            pl.BlockSpec((1, 1, head_dim), lambda b, h: (b, h, 0)),
            pl.BlockSpec((1, 1, max_seq, head_dim), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, max_seq, head_dim), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec((1,), lambda b, h: (b,)),
        ],
        out_specs=pl.BlockSpec((1, 1, head_dim), lambda b, h: (b, h, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, n_heads, head_dim), jnp.float32),
        interpret=True,
    )(
        q.astype(jnp.float32),
        k.astype(jnp.float32),
        v.astype(jnp.float32),
        seq_lens.astype(jnp.int32),
    )


def _paged_kernel(q_ref, kp_ref, vp_ref, table_ref, len_ref, o_ref, *, scale):
    q = q_ref[0, 0]  # (head_dim,) query slice of this (batch, head) task
    k_pool = kp_ref[:, 0]  # (n_pool, page, head_dim) this head's page pool
    v_pool = vp_ref[:, 0]
    table = table_ref[0]  # (n_chain,) page ids of this sequence's chain
    n = len_ref[0]  # cached positions (raggedness over the flattened chain)
    n_chain, page, head_dim = table.shape[0], k_pool.shape[1], k_pool.shape[2]
    # gather the chain and flatten it into the virtual contiguous panel —
    # the vectorized equivalent of streaming panel_runs page by page
    k = jnp.take(k_pool, table, axis=0).reshape(n_chain * page, head_dim)
    v = jnp.take(v_pool, table, axis=0).reshape(n_chain * page, head_dim)
    idx = jax.lax.broadcasted_iota(jnp.int32, (n_chain * page, 1), 0)[:, 0]
    scores = jnp.where(idx < n, (k @ q) * scale, -jnp.inf)
    m = jnp.max(scores)
    e = jnp.where(idx < n, jnp.exp(scores - m), 0.0)
    o_ref[0, 0] = (e / jnp.sum(e)) @ v


def attn_decode_paged(
    q: jax.Array,
    k_pages: jax.Array,
    v_pages: jax.Array,
    page_table: jax.Array,
    seq_lens: jax.Array,
) -> jax.Array:
    """Ragged batched decode attention over a shared page pool.

    q:          (batch, n_heads, head_dim)  one query token per sequence
    k_pages:    (n_pool, n_heads, page_positions, head_dim)  page pool
    v_pages:    (n_pool, n_heads, page_positions, head_dim)
    page_table: (batch, n_chain) int32  pool ids of each sequence's chain,
                in position order; entries past the sequence's last page are
                arbitrary valid ids (their positions are masked)
    seq_lens:   (batch,) int32  cached positions per sequence
                (1..n_chain*page_positions)

    Sequences sharing prompt-prefix pages simply repeat pool ids in their
    tables. Returns (batch, n_heads, head_dim) context rows.
    """
    bsz, n_heads, head_dim = q.shape
    n_pool, _, page, _ = k_pages.shape
    assert k_pages.shape == v_pages.shape == (n_pool, n_heads, page, head_dim), (
        q.shape,
        k_pages.shape,
        v_pages.shape,
    )
    n_chain = page_table.shape[1]
    assert page_table.shape == (bsz, n_chain), page_table.shape
    assert seq_lens.shape == (bsz,), seq_lens.shape
    scale = 1.0 / float(head_dim) ** 0.5
    return pl.pallas_call(
        functools.partial(_paged_kernel, scale=scale),
        grid=(bsz, n_heads),
        in_specs=[
            pl.BlockSpec((1, 1, head_dim), lambda b, h: (b, h, 0)),
            pl.BlockSpec((n_pool, 1, page, head_dim), lambda b, h: (0, h, 0, 0)),
            pl.BlockSpec((n_pool, 1, page, head_dim), lambda b, h: (0, h, 0, 0)),
            pl.BlockSpec((1, n_chain), lambda b, h: (b, 0)),
            pl.BlockSpec((1,), lambda b, h: (b,)),
        ],
        out_specs=pl.BlockSpec((1, 1, head_dim), lambda b, h: (b, h, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, n_heads, head_dim), jnp.float32),
        interpret=True,
    )(
        q.astype(jnp.float32),
        k_pages.astype(jnp.float32),
        v_pages.astype(jnp.float32),
        page_table.astype(jnp.int32),
        seq_lens.astype(jnp.int32),
    )


def _paged_q8_kernel(
    q_ref, kp_ref, vp_ref, ks_ref, vs_ref, table_ref, len_ref, o_ref, *, scale
):
    q = q_ref[0, 0]  # (head_dim,) query slice of this (batch, head) task
    k_pool = kp_ref[:, 0]  # (n_pool, page, head_dim) int8 codes, this head
    v_pool = vp_ref[:, 0]
    k_sc = ks_ref[:, 0]  # (n_pool, page) per-position dequant scales
    v_sc = vs_ref[:, 0]
    table = table_ref[0]  # (n_chain,) page ids of this sequence's chain
    n = len_ref[0]
    n_chain, page, head_dim = table.shape[0], k_pool.shape[1], k_pool.shape[2]
    # gather chain + dequantize in VMEM: codes widen to f32 and pick up
    # their position's scale; the f32 panel exists only on-chip
    k = (
        jnp.take(k_pool, table, axis=0).astype(jnp.float32)
        * jnp.take(k_sc, table, axis=0)[..., None]
    ).reshape(n_chain * page, head_dim)
    v = (
        jnp.take(v_pool, table, axis=0).astype(jnp.float32)
        * jnp.take(v_sc, table, axis=0)[..., None]
    ).reshape(n_chain * page, head_dim)
    idx = jax.lax.broadcasted_iota(jnp.int32, (n_chain * page, 1), 0)[:, 0]
    scores = jnp.where(idx < n, (k @ q) * scale, -jnp.inf)
    m = jnp.max(scores)
    e = jnp.where(idx < n, jnp.exp(scores - m), 0.0)
    o_ref[0, 0] = (e / jnp.sum(e)) @ v


def attn_decode_paged_q8(
    q: jax.Array,
    k_pages: jax.Array,
    v_pages: jax.Array,
    k_scales: jax.Array,
    v_scales: jax.Array,
    page_table: jax.Array,
    seq_lens: jax.Array,
) -> jax.Array:
    """Ragged batched decode attention over a shared int8 page pool.

    q:          (batch, n_heads, head_dim) f32  one query token per sequence
    k_pages:    (n_pool, n_heads, page_positions, head_dim) int8 codes
    v_pages:    (n_pool, n_heads, page_positions, head_dim) int8 codes
    k_scales:   (n_pool, n_heads, page_positions) f32  per-position scales
    v_scales:   (n_pool, n_heads, page_positions) f32
    page_table: (batch, n_chain) int32  pool ids of each sequence's chain
    seq_lens:   (batch,) int32  cached positions per sequence

    Position `t` of page `p`/head `h` dequantizes as
    `k_pages[p, h, t] * k_scales[p, h, t]` — the scale travels with its
    page, so prefix-shared and CoW-copied chains stay consistent for free.
    Returns (batch, n_heads, head_dim) f32 context rows.
    """
    bsz, n_heads, head_dim = q.shape
    n_pool, _, page, _ = k_pages.shape
    assert k_pages.shape == v_pages.shape == (n_pool, n_heads, page, head_dim), (
        q.shape,
        k_pages.shape,
        v_pages.shape,
    )
    assert k_scales.shape == v_scales.shape == (n_pool, n_heads, page), (
        k_scales.shape,
        v_scales.shape,
    )
    n_chain = page_table.shape[1]
    assert page_table.shape == (bsz, n_chain), page_table.shape
    assert seq_lens.shape == (bsz,), seq_lens.shape
    scale = 1.0 / float(head_dim) ** 0.5
    return pl.pallas_call(
        functools.partial(_paged_q8_kernel, scale=scale),
        grid=(bsz, n_heads),
        in_specs=[
            pl.BlockSpec((1, 1, head_dim), lambda b, h: (b, h, 0)),
            pl.BlockSpec((n_pool, 1, page, head_dim), lambda b, h: (0, h, 0, 0)),
            pl.BlockSpec((n_pool, 1, page, head_dim), lambda b, h: (0, h, 0, 0)),
            pl.BlockSpec((n_pool, 1, page), lambda b, h: (0, h, 0)),
            pl.BlockSpec((n_pool, 1, page), lambda b, h: (0, h, 0)),
            pl.BlockSpec((1, n_chain), lambda b, h: (b, 0)),
            pl.BlockSpec((1,), lambda b, h: (b,)),
        ],
        out_specs=pl.BlockSpec((1, 1, head_dim), lambda b, h: (b, h, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, n_heads, head_dim), jnp.float32),
        interpret=True,
    )(
        q.astype(jnp.float32),
        k_pages.astype(jnp.int8),
        v_pages.astype(jnp.int8),
        k_scales.astype(jnp.float32),
        v_scales.astype(jnp.float32),
        page_table.astype(jnp.int32),
        seq_lens.astype(jnp.int32),
    )
