"""Layer-1 Pallas kernel: ragged-batch decode attention over KV panels.

TPU twin of the Rust serve path's blocked attention kernel
(`rust/src/model/attention.rs`), mirroring its blocking scheme:

- **Work decomposition**: the grid iterates over `(batch, head)` — exactly
  the Rust kernel's one-task-per-(sequence, head) split. Each step owns one
  query head-slice and one `max_seq × head_dim` K/V panel in VMEM, the
  head-major layout `serve::KvCache` stores natively.
- **Raggedness**: sequences in the batch have mixed lengths; `seq_lens[b]`
  masks positions `>= len` to `-inf` before the softmax, the vectorized
  equivalent of the Rust kernel slicing its panel at `n_ctx`.
- **Softmax**: the same two-pass max/exp/normalize the Rust kernel runs —
  no online rescaling, so both twins agree with the scalar reference to
  f32 rounding.

Lowered with `interpret=True`: the CPU PJRT plugin cannot run Mosaic
custom-calls; correctness is asserted against `ref.attn_decode_ref`.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(q_ref, k_ref, v_ref, len_ref, o_ref, *, scale):
    q = q_ref[0, 0]  # (head_dim,) query slice of this (batch, head) task
    k = k_ref[0, 0]  # (max_seq, head_dim) K panel
    v = v_ref[0, 0]  # (max_seq, head_dim) V panel
    n = len_ref[0]  # this sequence's cached length
    # pass 1: scores over the panel, masked past the ragged length
    idx = jax.lax.broadcasted_iota(jnp.int32, (k.shape[0], 1), 0)[:, 0]
    scores = jnp.where(idx < n, (k @ q) * scale, -jnp.inf)
    # pass 2: two-pass softmax (max, then exp/normalize), as in the Rust twin
    m = jnp.max(scores)
    e = jnp.where(idx < n, jnp.exp(scores - m), 0.0)
    # pass 3: weighted V-sum
    o_ref[0, 0] = (e / jnp.sum(e)) @ v


def attn_decode(q: jax.Array, k: jax.Array, v: jax.Array, seq_lens: jax.Array) -> jax.Array:
    """Ragged batched decode attention.

    q:        (batch, n_heads, head_dim)  one query token per sequence
    k, v:     (batch, n_heads, max_seq, head_dim)  head-major KV panels
    seq_lens: (batch,) int32  cached positions per sequence (1..max_seq)

    Returns (batch, n_heads, head_dim) context rows.
    """
    bsz, n_heads, head_dim = q.shape
    assert k.shape == v.shape == (bsz, n_heads, k.shape[2], head_dim), (q.shape, k.shape, v.shape)
    assert seq_lens.shape == (bsz,), seq_lens.shape
    max_seq = k.shape[2]
    scale = 1.0 / float(head_dim) ** 0.5
    return pl.pallas_call(
        functools.partial(_kernel, scale=scale),
        grid=(bsz, n_heads),
        in_specs=[
            pl.BlockSpec((1, 1, head_dim), lambda b, h: (b, h, 0)),
            pl.BlockSpec((1, 1, max_seq, head_dim), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, max_seq, head_dim), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec((1,), lambda b, h: (b,)),
        ],
        out_specs=pl.BlockSpec((1, 1, head_dim), lambda b, h: (b, h, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, n_heads, head_dim), jnp.float32),
        interpret=True,
    )(
        q.astype(jnp.float32),
        k.astype(jnp.float32),
        v.astype(jnp.float32),
        seq_lens.astype(jnp.int32),
    )
