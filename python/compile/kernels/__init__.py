"""Layer-1 Pallas kernels (build-time only; lowered into the L2 HLO)."""

from .armor_matmul import armor_matmul, masked_armor_matmul
from .attn_decode import attn_decode, attn_decode_paged, attn_decode_paged_q8
from .mask_init import mask_topk_nm
from .proxy_loss import proxy_loss
from .sparse_matmul_q8 import sparse_matmul_q8
from .sparse_update import sparse_group_ls

__all__ = [
    "armor_matmul",
    "attn_decode",
    "attn_decode_paged",
    "attn_decode_paged_q8",
    "masked_armor_matmul",
    "mask_topk_nm",
    "proxy_loss",
    "sparse_group_ls",
    "sparse_matmul_q8",
]
