"""Layer-1 Pallas kernel: the NoWag weighted squared-Frobenius proxy loss
(paper Eq. 2), as a tiled grid reduction.

Each grid step loads one `(tr × d_in)` row panel of `w_bar`/`w_hat` plus the
activation weights `d`, reduces it on the VPU, and accumulates into a single
scalar output block (revisited across the sequential grid — the standard
Pallas reduction idiom)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(wbar_ref, what_ref, d_ref, o_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    diff = wbar_ref[...] - what_ref[...]
    o_ref[0, 0] += jnp.sum(diff * diff * d_ref[0][None, :])


def proxy_loss(w_bar: jax.Array, w_hat: jax.Array, d: jax.Array, tile_rows: int = 32) -> jax.Array:
    """`Σ_ij (w_bar − w_hat)²_ij d_j` → scalar (shape (1, 1) squeezed)."""
    rows, cols = w_bar.shape
    tr = min(tile_rows, rows)
    while rows % tr != 0:
        tr -= 1
    grid = (rows // tr,)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tr, cols), lambda i: (i, 0)),
            pl.BlockSpec((tr, cols), lambda i: (i, 0)),
            pl.BlockSpec((1, cols), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 1), jnp.float32),
        interpret=True,
    )(
        w_bar.astype(jnp.float32),
        w_hat.astype(jnp.float32),
        d.reshape(1, -1).astype(jnp.float32),
    )
    return out[0, 0]
