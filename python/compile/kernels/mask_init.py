"""Layer-1 Pallas kernel: N:M top-N mask initialization (paper Eq. 3).

Branch-free rank-by-comparison inside each M-wide group: an entry is kept
when fewer than N entries rank above it (strictly greater importance, or
equal importance at a lower column index — matching the Rust tie-break)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(n: int, m: int, imp_ref, o_ref):
    imp = imp_ref[...]  # (tr, cols)
    tr, cols = imp.shape
    g = imp.reshape(tr, cols // m, m)
    idx = jnp.arange(m)
    greater = g[..., None, :] > g[..., :, None]
    equal_lower = (g[..., None, :] == g[..., :, None]) & (idx[None, :] < idx[:, None])
    rank = jnp.sum(greater | equal_lower, axis=-1)
    o_ref[...] = (rank < n).astype(jnp.float32).reshape(tr, cols)


def mask_topk_nm(importance: jax.Array, n: int, m: int, tile_rows: int = 32) -> jax.Array:
    """0/1 float mask keeping the top-`n` of every `m` consecutive columns."""
    rows, cols = importance.shape
    assert cols % m == 0, f"cols {cols} not divisible by M={m}"
    tr = min(tile_rows, rows)
    while rows % tr != 0:
        tr -= 1
    return pl.pallas_call(
        functools.partial(_kernel, n, m),
        grid=(rows // tr,),
        in_specs=[pl.BlockSpec((tr, cols), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((tr, cols), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, cols), jnp.float32),
        interpret=True,
    )(importance.astype(jnp.float32))
