"""Pure-jnp oracles for every Pallas kernel.

These are the correctness ground truth: `python/tests/test_kernels.py`
sweeps shapes and dtypes with hypothesis and asserts each Pallas kernel
matches its oracle to float32 tolerance.
"""

from __future__ import annotations

import jax.numpy as jnp


def armor_matmul_ref(a_blocks, core, b_blocks):
    """Reconstruct `Ŵ = A · core · B` with block-diagonal A, B.

    a_blocks: (nbo, db, db), core: (d_out, d_in), b_blocks: (nbi, db, db).
    """
    nbo, db, _ = a_blocks.shape
    nbi = b_blocks.shape[0]
    s = core.reshape(nbo, db, nbi, db)
    # A_i @ S[i, :, j, :] @ B_j  for every block pair
    out = jnp.einsum("ipq,iqjr,jrs->ipjs", a_blocks, s, b_blocks)
    return out.reshape(nbo * db, nbi * db)


def attn_decode_ref(q, k, v, seq_lens):
    """Ragged batched decode attention (serve-path twin).

    q: (batch, n_heads, head_dim); k, v: (batch, n_heads, max_seq, head_dim);
    seq_lens: (batch,) — positions >= seq_lens[b] are masked out of sequence
    b's softmax. Returns (batch, n_heads, head_dim).
    """
    q = q.astype(jnp.float32)
    k = k.astype(jnp.float32)
    v = v.astype(jnp.float32)
    scale = 1.0 / float(q.shape[-1]) ** 0.5
    scores = jnp.einsum("bhd,bhsd->bhs", q, k) * scale
    idx = jnp.arange(k.shape[2])
    mask = idx[None, None, :] < seq_lens[:, None, None]
    scores = jnp.where(mask, scores, -jnp.inf)
    m = jnp.max(scores, axis=-1, keepdims=True)
    e = jnp.where(mask, jnp.exp(scores - m), 0.0)
    w = e / jnp.sum(e, axis=-1, keepdims=True)
    return jnp.einsum("bhs,bhsd->bhd", w, v)


def q8_dequant_ref(codes, scales, group):
    """Dequantize a packed int8 value plane: one f32 scale per `group`
    consecutive packed values per row (last group ragged), matching
    `sparsity::q8_quantize`'s symmetric layout."""
    rows, n_packed = codes.shape
    expanded = jnp.repeat(scales.astype(jnp.float32), group, axis=1)[:, :n_packed]
    return codes.astype(jnp.float32) * expanded


def sparse_matmul_q8_ref(qvalues, col_idx, scales, x, group):
    """Dequantize-then-matmul oracle for the fused `sparse_matmul_q8`
    kernel: scatter the dequantized survivors into a dense matrix and run
    the dense contraction (the survivors' column indices are distinct
    within a row by 2:4 construction)."""
    rows, n_packed = qvalues.shape
    cols = x.shape[0]
    w = q8_dequant_ref(qvalues, scales, group)
    dense = jnp.zeros((rows, cols), dtype=jnp.float32)
    r_idx = jnp.broadcast_to(jnp.arange(rows)[:, None], (rows, n_packed))
    dense = dense.at[r_idx, col_idx].set(w)
    return dense @ x.astype(jnp.float32)


def attn_decode_paged_q8_ref(q, k_pages, v_pages, k_scales, v_scales, page_table, seq_lens):
    """Dequantize the int8 page pool (per-position scales travel with their
    page), assemble each sequence's virtual panel through its page table,
    and defer to the contiguous `attn_decode_ref` oracle."""
    k = k_pages.astype(jnp.float32) * k_scales.astype(jnp.float32)[..., None]
    v = v_pages.astype(jnp.float32) * v_scales.astype(jnp.float32)[..., None]
    n_heads, page = k.shape[1], k.shape[2]
    bsz, n_chain = page_table.shape
    gathered_k = jnp.moveaxis(k[page_table], 2, 1).reshape(bsz, n_heads, n_chain * page, -1)
    gathered_v = jnp.moveaxis(v[page_table], 2, 1).reshape(bsz, n_heads, n_chain * page, -1)
    return attn_decode_ref(q, gathered_k, gathered_v, seq_lens)


def proxy_loss_ref(w_bar, w_hat, d):
    """NoWag proxy loss: Σ_ij (w_bar − w_hat)²_ij d_j  (paper Eq. 2)."""
    diff = (w_bar - w_hat).astype(jnp.float32)
    return jnp.sum(diff * diff * d[None, :].astype(jnp.float32))


def mask_topk_nm_ref(importance, n, m):
    """Top-n-of-m mask per row group (paper Eq. 3), ties broken by lower
    column index — matching `sparsity::nm_mask_from_importance`."""
    rows, cols = importance.shape
    g = importance.reshape(rows, cols // m, m)
    idx = jnp.arange(m)
    # rank = #entries strictly greater, plus #equal entries with lower index
    greater = g[..., None, :] > g[..., :, None]  # [r, grp, t, u]: imp_u > imp_t
    equal_lower = (g[..., None, :] == g[..., :, None]) & (idx[None, :] < idx[:, None])
    rank = jnp.sum(greater | equal_lower, axis=-1)
    mask = (rank < n).astype(jnp.float32)
    return mask.reshape(rows, cols)


def group_ls_ref(e, a_col, u_rows, d, cur_vals, combos):
    """Closed-form mask-sweep least squares for one selected sparse group
    (paper Eq. 7–9). All-jnp reference for the `sparse_group_ls` kernel.

    e:        (db, db)  block residual  E = W̄blk − (A S B)blk
    a_col:    (db,)     A^{(i)}_{:, i'}
    u_rows:   (m, db)   the m rows of B^{(j)} touched by the group
    d:        (db,)     activation weights for the block's columns
    cur_vals: (m,)      current core values of the group
    combos:   (C, n)    integer index combinations (C(m,n) of them)

    Returns (best_combo_idx, best_vals (n,), gains (C,)).
    """
    a_sq = jnp.sum(a_col * a_col)
    # v = ΔWᵀ a = Eᵀ a + ‖a‖² Σ_t s_t u_t
    v = e.T @ a_col + a_sq * (cur_vals @ u_rows)
    # weighted grams
    g_full = jnp.einsum("td,d,ud->tu", u_rows, d, u_rows)  # (m, m)
    r_full = u_rows @ (d * v)  # (m,)

    gains = []
    vals_all = []
    for c in range(combos.shape[0]):
        combo = combos[c]
        gs = g_full[jnp.ix_(combo, combo)]
        rs = r_full[combo]
        w = jnp.linalg.pinv(gs, rtol=1e-10) @ rs
        gain = jnp.where(a_sq > 1e-30, rs @ w / a_sq, 0.0)
        vals = jnp.where(a_sq > 1e-30, w / a_sq, jnp.zeros_like(w))
        gains.append(gain)
        vals_all.append(vals)
    gains = jnp.stack(gains)
    vals_all = jnp.stack(vals_all)
    best = jnp.argmax(gains)
    return best, vals_all[best], gains


def nowag_normalize_ref(w, eps=1e-12):
    """Row/column normalization (paper §3.2), matching `normalize/mod.rs`."""
    r1 = jnp.sqrt(jnp.sum(w * w, axis=0))
    r1 = jnp.where(r1 <= eps, 1.0, r1)
    w1 = w / r1[None, :]
    r2 = jnp.sqrt(jnp.sum(w1 * w1, axis=1))
    r2 = jnp.where(r2 <= eps, 1.0, r2)
    w_bar = w1 / r2[:, None]
    return w_bar, r1, r2
