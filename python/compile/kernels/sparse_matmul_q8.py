"""Layer-1 Pallas kernel: fused dequant 2:4 sparse matmul over an int8
value plane.

TPU twin of `Compressed24Q8::matmul_q8` (`rust/src/sparsity/compressed.rs`),
mirroring its execution plan:

- **One-shot metadata decode**: the kernel takes the 2:4 metadata already
  decoded into absolute column indices (`col_idx`), exactly like the Rust
  path's `decode_meta_columns` — the nibble decode is hoisted out of the
  hot loop on both sides.
- **Value plane**: `qvalues` holds the packed survivors as symmetric int8
  codes, one f32 scale per `group` consecutive packed values of a row
  (`group` even, so the two survivors of a 4-column group always share a
  scale). Dequantization happens in VMEM as the codes stream — the f32
  weight matrix is never materialized, the HBM traffic is ~¼ of the
  f32-compressed layout.
- **Work decomposition**: grid over output rows; each step owns one row's
  codes/scales/column indices, gathers the matching rows of the activation
  slab `x` (resident in VMEM across the whole grid, the analog of the Rust
  kernel's cache-resident `X[:, jb..jend]` batch block), and contracts.

Lowered with `interpret=True`: the CPU PJRT plugin cannot run Mosaic
custom-calls; correctness is asserted against `ref.sparse_matmul_q8_ref`.
A production Mosaic lowering would tile rows × batch over the MXU and
prefetch `col_idx` via SMEM (`PrefetchScalarGridSpec`).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(qv_ref, idx_ref, sc_ref, x_ref, o_ref, *, group):
    qv = qv_ref[0]  # (2g,) int8 packed values of this output row
    idx = idx_ref[0]  # (2g,) absolute column indices (decoded metadata)
    sc = sc_ref[0]  # (n_groups,) per-group scales
    x = x_ref[...]  # (cols, batch) activation slab, VMEM-resident
    n_packed = qv.shape[0]
    # fused dequant: codes widen to f32 and pick up their group's scale in
    # registers; `repeat` broadcasts each scale over its `group` codes (the
    # last group of a row may be ragged -> slice back to n_packed)
    w = qv.astype(jnp.float32) * jnp.repeat(sc, group)[:n_packed]
    # gather the two surviving activation rows per 4-column group and
    # contract: (2g,) @ (2g, batch)
    o_ref[0] = w @ jnp.take(x, idx, axis=0)


def sparse_matmul_q8(
    qvalues: jax.Array,
    col_idx: jax.Array,
    scales: jax.Array,
    x: jax.Array,
    *,
    group: int,
) -> jax.Array:
    """Fused dequant 2:4 sparse matmul `y = Ŵ x` from the packed layout.

    qvalues: (rows, 2·g) int8   packed survivors, g = cols // 4 groups/row
    col_idx: (rows, 2·g) int32  absolute column index of each survivor
    scales:  (rows, ceil(2g / group)) f32  per-group dequant scales
    x:       (cols, batch) f32  activation slab
    group:   packed values per scale (even, matching the Rust plane)

    Returns (rows, batch) f32.
    """
    rows, n_packed = qvalues.shape
    assert col_idx.shape == (rows, n_packed), (qvalues.shape, col_idx.shape)
    assert group >= 2 and group % 2 == 0, group
    n_groups = max(-(-n_packed // group), 1)
    assert scales.shape == (rows, n_groups), (scales.shape, n_groups)
    cols, batch = x.shape
    assert n_packed == (cols // 4) * 2, (n_packed, cols)
    return pl.pallas_call(
        functools.partial(_kernel, group=group),
        grid=(rows,),
        in_specs=[
            pl.BlockSpec((1, n_packed), lambda r: (r, 0)),
            pl.BlockSpec((1, n_packed), lambda r: (r, 0)),
            pl.BlockSpec((1, n_groups), lambda r: (r, 0)),
            pl.BlockSpec((cols, batch), lambda r: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, batch), lambda r: (r, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, batch), jnp.float32),
        interpret=True,
    )(
        qvalues.astype(jnp.int8),
        col_idx.astype(jnp.int32),
        scales.astype(jnp.float32),
        x.astype(jnp.float32),
    )
