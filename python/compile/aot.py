"""AOT lowering: JAX/Pallas Layer-2 graphs → HLO **text** artifacts.

HLO text (not `.serialize()`) is the interchange format: jax ≥ 0.5 emits
HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1 (the
version behind the published `xla` 0.1.6 crate) rejects; the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Artifacts produced (manifest.json describes all of them):
- `cont_steps_{dout}x{din}_b{db}`  — K fused Adam steps on (A, B, W')
- `proxy_loss_{dout}x{din}_b{db}`  — Pallas-kernel proxy loss evaluation
- `mask_init_{dout}x{din}`         — Pallas top-2:4 NoWag-P mask init
- `gpt_nll_{tag}`                  — per-sequence mean NLL for fast eval
"""

from __future__ import annotations

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M

K_STEPS = 10  # Adam steps fused per PJRT call


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def lower_cont_steps(d_out: int, d_in: int, db: int):
    nbo, nbi = d_out // db, d_in // db
    fn = functools.partial(M.armor_cont_steps, k_steps=K_STEPS)
    specs = [
        f32(nbo, db, db),  # a
        f32(nbi, db, db),  # b
        f32(d_out, d_in),  # wp
        f32(d_out, d_in),  # mask
        f32(d_out, d_in),  # w_bar
        f32(d_in),         # d
        f32(nbo, db, db), f32(nbo, db, db),  # ma, va
        f32(nbi, db, db), f32(nbi, db, db),  # mb, vb
        f32(d_out, d_in), f32(d_out, d_in),  # mw, vw
        f32(),             # t0
        f32(),             # lr
    ]
    lowered = jax.jit(fn).lower(*specs)
    in_shapes = [list(s.shape) for s in specs]
    out_shapes = in_shapes[:3] + in_shapes[6:13] + [[]]
    return lowered, in_shapes, out_shapes


def lower_proxy_loss(d_out: int, d_in: int, db: int):
    nbo, nbi = d_out // db, d_in // db
    specs = [f32(nbo, db, db), f32(nbi, db, db), f32(d_out, d_in), f32(d_out, d_in),
             f32(d_out, d_in), f32(d_in)]
    lowered = jax.jit(M.proxy_loss_pallas).lower(*specs)
    return lowered, [list(s.shape) for s in specs], [[]]


def lower_mask_init(d_out: int, d_in: int):
    specs = [f32(d_out, d_in), f32(d_in)]
    lowered = jax.jit(M.armor_init).lower(*specs)
    return lowered, [list(s.shape) for s in specs], [[d_out, d_in]]


def lower_gpt_nll(cfg: dict, batch: int, seq: int):
    params_spec = {
        k: jax.ShapeDtypeStruct(v.shape, jnp.float32)
        for k, v in M.init_params(cfg, jax.random.PRNGKey(0)).items()
    }
    names = sorted(params_spec)

    def fn(*args):
        params = dict(zip(names, args[:-1]))
        return M.batch_nll(params, cfg, args[-1])

    specs = [params_spec[k] for k in names] + [
        jax.ShapeDtypeStruct((batch, seq), jnp.int32)
    ]
    lowered = jax.jit(fn).lower(*specs)
    in_shapes = [list(s.shape) for s in specs]
    return lowered, in_shapes, [[batch]], names


def prunable_shapes(cfg: dict) -> list[tuple[int, int]]:
    d, dff = cfg["d_model"], cfg["d_ff"]
    return sorted({(d, d), (dff, d), (d, dff)})


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--config", default="../configs/tiny.json")
    ap.add_argument("--d-block", type=int, default=32)
    ap.add_argument("--eval-batch", type=int, default=8)
    ap.add_argument("--skip-gpt", action="store_true", help="only ARMOR artifacts")
    args = ap.parse_args()

    with open(args.config) as f:
        cfg = json.load(f)
    os.makedirs(args.out_dir, exist_ok=True)
    db = args.d_block

    artifacts = []

    def emit(name: str, lowered, in_shapes, out_shapes, meta: dict):
        path = f"{name}.hlo.txt"
        text = to_hlo_text(lowered)
        with open(os.path.join(args.out_dir, path), "w") as f:
            f.write(text)
        artifacts.append({
            "name": name,
            "path": path,
            "input_shapes": in_shapes,
            "output_shapes": out_shapes,
            "meta": meta,
        })
        print(f"[aot] {name}: {len(text)} chars", flush=True)

    for d_out, d_in in prunable_shapes(cfg):
        assert d_out % db == 0 and d_in % db == 0, f"d_block {db} must divide {(d_out, d_in)}"
        lowered, ins, outs = lower_cont_steps(d_out, d_in, db)
        emit(f"cont_steps_{d_out}x{d_in}_b{db}", lowered, ins, outs,
             {"d_block": db, "k_steps": K_STEPS, "kind": "cont_steps"})
        lowered, ins, outs = lower_proxy_loss(d_out, d_in, db)
        emit(f"proxy_loss_{d_out}x{d_in}_b{db}", lowered, ins, outs,
             {"d_block": db, "kind": "proxy_loss"})
        lowered, ins, outs = lower_mask_init(d_out, d_in)
        emit(f"mask_init_{d_out}x{d_in}", lowered, ins, outs, {"kind": "mask_init"})

    if not args.skip_gpt:
        seq = cfg["max_seq"]
        lowered, ins, outs, names = lower_gpt_nll(cfg, args.eval_batch, seq)
        emit(f"gpt_nll_b{args.eval_batch}", lowered, ins, outs,
             {"kind": "gpt_nll", "param_names": names, "batch": args.eval_batch, "seq": seq})

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump({"artifacts": artifacts, "config": cfg}, f, indent=1)
    print(f"[aot] wrote {len(artifacts)} artifacts + manifest to {args.out_dir}")


if __name__ == "__main__":
    main()
