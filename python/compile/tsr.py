"""`.tsr` tensor-bundle format, mirroring `rust/src/io/tsr.rs`.

Layout: magic b"TSR1" | u64-LE header length | JSON header | f32-LE payload.
Header: {"tensors": {name: {"shape": [...], "offset": elems}}, "meta": {...}}
Tensors are concatenated in sorted-name order (BTreeMap order on the Rust
side) — the writer here enforces the same ordering.
"""

from __future__ import annotations

import json
import struct

import numpy as np

MAGIC = b"TSR1"


def save_tsr(path: str, tensors: dict[str, np.ndarray], meta: dict | None = None) -> None:
    """Write a bundle. Tensors are converted to float32."""
    names = sorted(tensors)
    header_tensors: dict[str, dict] = {}
    offset = 0
    arrays = []
    for name in names:
        arr = np.ascontiguousarray(tensors[name], dtype=np.float32)
        header_tensors[name] = {"shape": list(arr.shape), "offset": offset}
        offset += arr.size
        arrays.append(arr)
    header = json.dumps({"tensors": header_tensors, "meta": meta or {}}).encode()
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<Q", len(header)))
        f.write(header)
        for arr in arrays:
            f.write(arr.astype("<f4").tobytes())


def load_tsr(path: str) -> tuple[dict[str, np.ndarray], dict]:
    """Read a bundle, returning (tensors, meta)."""
    with open(path, "rb") as f:
        magic = f.read(4)
        if magic != MAGIC:
            raise ValueError(f"{path} is not a TSR1 bundle")
        (hlen,) = struct.unpack("<Q", f.read(8))
        header = json.loads(f.read(hlen))
        payload = np.frombuffer(f.read(), dtype="<f4")
    tensors = {}
    for name, spec in header["tensors"].items():
        shape = spec["shape"]
        n = int(np.prod(shape)) if shape else 1
        off = spec["offset"]
        tensors[name] = payload[off : off + n].reshape(shape).copy()
    return tensors, header.get("meta", {})
