"""Layer-2 JAX compute graphs.

Two families, both AOT-lowered to HLO text by `aot.py`:

1. **ARMOR optimizer steps** — `armor_cont_steps` runs K fused Adam steps on
   (A, B, W') under a fixed mask (paper §3.3.1, joint-Adam variant). The
   gradients come from `jax.grad` of the jnp proxy loss; the reported loss is
   computed through the Layer-1 Pallas kernels (`kernels.armor_matmul` +
   `kernels.proxy_loss`) so the kernels lower into the same HLO module.

2. **The tiny GPT** — forward / per-sequence NLL, mirroring
   `rust/src/model/gpt.rs` exactly (pre-LN, learned positions, tanh-GELU,
   tied head) so build-time-trained weights run natively in Rust.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import kernels

# --------------------------------------------------------------------------
# ARMOR Layer-2 graphs
# --------------------------------------------------------------------------


def proxy_loss_jnp(a_blocks, b_blocks, w_prime, mask, w_bar, d):
    """Differentiable proxy loss (paper Eq. 2) in plain jnp."""
    nbo, db, _ = a_blocks.shape
    nbi = b_blocks.shape[0]
    core = (w_prime * mask).reshape(nbo, db, nbi, db)
    w_hat = jnp.einsum("ipq,iqjr,jrs->ipjs", a_blocks, core, b_blocks).reshape(
        nbo * db, nbi * db
    )
    diff = w_bar - w_hat
    return jnp.sum(diff * diff * d[None, :])


def proxy_loss_pallas(a_blocks, b_blocks, w_prime, mask, w_bar, d):
    """Proxy loss evaluated through the Layer-1 Pallas kernels."""
    w_hat = kernels.masked_armor_matmul(a_blocks, w_prime, mask, b_blocks)
    return kernels.proxy_loss(w_bar, w_hat, d)


ADAM_B1, ADAM_B2, ADAM_EPS = 0.9, 0.999, 1e-8


def armor_cont_steps(a, b, wp, mask, w_bar, d, ma, va, mb, vb, mw, vw, t0, lr, *, k_steps: int):
    """K fused joint-Adam steps (the hot path the Rust runtime calls).

    Shapes: a (nbo,db,db), b (nbi,db,db), wp/mask/w_bar (d_out,d_in),
    d (d_in,), moments matching their parameters, t0/lr scalars.
    Returns updated (a, b, wp, moments..., t, loss) — loss computed through
    the Pallas kernels after the final step.
    """

    grad_fn = jax.grad(proxy_loss_jnp, argnums=(0, 1, 2))

    def adam(p, g, m, v, t):
        m = ADAM_B1 * m + (1 - ADAM_B1) * g
        v = ADAM_B2 * v + (1 - ADAM_B2) * g * g
        mhat = m / (1 - ADAM_B1**t)
        vhat = v / (1 - ADAM_B2**t)
        return p - lr * mhat / (jnp.sqrt(vhat) + ADAM_EPS), m, v

    def body(_, state):
        a, b, wp, ma, va, mb, vb, mw, vw, t = state
        ga, gb, gw = grad_fn(a, b, wp, mask, w_bar, d)
        gw = gw * mask  # ∇W' = G ⊙ M
        t = t + 1.0
        a, ma, va = adam(a, ga, ma, va, t)
        b, mb, vb = adam(b, gb, mb, vb, t)
        wp, mw, vw = adam(wp, gw, mw, vw, t)
        return (a, b, wp, ma, va, mb, vb, mw, vw, t)

    state = (a, b, wp, ma, va, mb, vb, mw, vw, t0)
    state = jax.lax.fori_loop(0, k_steps, body, state)
    a, b, wp, ma, va, mb, vb, mw, vw, t = state
    loss = proxy_loss_pallas(a, b, wp, mask, w_bar, d)
    return a, b, wp, ma, va, mb, vb, mw, vw, t, loss


def armor_init(w_bar, d, *, n: int = 2, m: int = 4):
    """NoWag-P mask init (paper Eq. 3) through the Pallas top-N kernel."""
    importance = w_bar * w_bar * d[None, :]
    return kernels.mask_topk_nm(importance, n, m)


# --------------------------------------------------------------------------
# Tiny GPT (must mirror rust/src/model/gpt.rs bit-for-bit in structure)
# --------------------------------------------------------------------------


def init_params(cfg: dict, key) -> dict:
    """Random init. cfg keys: vocab, d_model, n_layers, n_heads, d_ff,
    max_seq, optional moe {n_experts, top_k}."""
    d, dff = cfg["d_model"], cfg["d_ff"]
    std_w = 1.0 / d**0.5
    p = {}
    key, *ks = jax.random.split(key, 3)
    p["tok_embed"] = 0.05 * jax.random.normal(ks[0], (cfg["vocab"], d))
    p["pos_embed"] = 0.05 * jax.random.normal(ks[1], (cfg["max_seq"], d))
    for l in range(cfg["n_layers"]):
        for nm in ["ln1.g", "ln2.g"]:
            p[f"l{l}.{nm}"] = jnp.ones((d,))
        for nm in ["ln1.b", "ln2.b"]:
            p[f"l{l}.{nm}"] = jnp.zeros((d,))
        for w in ["wq", "wk", "wv", "wo"]:
            key, k1 = jax.random.split(key)
            p[f"l{l}.attn.{w}"] = std_w * jax.random.normal(k1, (d, d))
        if cfg.get("moe"):
            ne = cfg["moe"]["n_experts"]
            key, k1 = jax.random.split(key)
            p[f"l{l}.moe.router"] = std_w * jax.random.normal(k1, (ne, d))
            for e in range(ne):
                key, k1, k2 = jax.random.split(key, 3)
                p[f"l{l}.moe.e{e}.up"] = std_w * jax.random.normal(k1, (dff, d))
                p[f"l{l}.moe.e{e}.down"] = (1.0 / dff**0.5) * jax.random.normal(k2, (d, dff))
        else:
            key, k1, k2 = jax.random.split(key, 3)
            p[f"l{l}.mlp.up"] = std_w * jax.random.normal(k1, (dff, d))
            p[f"l{l}.mlp.down"] = (1.0 / dff**0.5) * jax.random.normal(k2, (d, dff))
    p["ln_f.g"] = jnp.ones((d,))
    p["ln_f.b"] = jnp.zeros((d,))
    return p


def _layer_norm(x, g, b):
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mean) ** 2, axis=-1, keepdims=True)
    return (x - mean) / jnp.sqrt(var + 1e-5) * g + b


def _gelu(x):
    c = 0.7978845608
    return 0.5 * x * (1.0 + jnp.tanh(c * (x + 0.044715 * x**3)))


def _attention(q, k, v, n_heads):
    """q,k,v: (S, d). Causal multi-head attention."""
    s, d = q.shape
    hd = d // n_heads
    q = q.reshape(s, n_heads, hd).transpose(1, 0, 2)  # (h, s, hd)
    k = k.reshape(s, n_heads, hd).transpose(1, 0, 2)
    v = v.reshape(s, n_heads, hd).transpose(1, 0, 2)
    scores = jnp.einsum("hid,hjd->hij", q, k) / hd**0.5
    causal = jnp.tril(jnp.ones((s, s), dtype=bool))
    scores = jnp.where(causal[None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("hij,hjd->hid", probs, v)  # (h, s, hd)
    return ctx.transpose(1, 0, 2).reshape(s, d)


def forward(params: dict, cfg: dict, tokens):
    """Logits for one sequence of token ids (S,) → (S, vocab)."""
    s = tokens.shape[0]
    x = params["tok_embed"][tokens] + params["pos_embed"][:s]
    for l in range(cfg["n_layers"]):
        xn = _layer_norm(x, params[f"l{l}.ln1.g"], params[f"l{l}.ln1.b"])
        q = xn @ params[f"l{l}.attn.wq"].T
        k = xn @ params[f"l{l}.attn.wk"].T
        v = xn @ params[f"l{l}.attn.wv"].T
        ctx = _attention(q, k, v, cfg["n_heads"])
        x = x + ctx @ params[f"l{l}.attn.wo"].T
        xn2 = _layer_norm(x, params[f"l{l}.ln2.g"], params[f"l{l}.ln2.b"])
        if cfg.get("moe"):
            x = x + _moe_mlp(params, cfg, l, xn2)
        else:
            h = _gelu(xn2 @ params[f"l{l}.mlp.up"].T)
            x = x + h @ params[f"l{l}.mlp.down"].T
    xf = _layer_norm(x, params["ln_f.g"], params["ln_f.b"])
    return xf @ params["tok_embed"].T


def _moe_mlp(params, cfg, l, xn):
    """Top-1 (switch) MoE with softmax gate — dense compute formulation
    (every expert runs, outputs gated by the routing one-hot; identical math
    to the Rust sparse routing)."""
    ne = cfg["moe"]["n_experts"]
    logits = xn @ params[f"l{l}.moe.router"].T  # (s, ne)
    probs = jax.nn.softmax(logits, axis=-1)
    best = jnp.argmax(logits, axis=-1)  # (s,)
    gate = jnp.take_along_axis(probs, best[:, None], axis=-1)  # (s, 1)
    onehot = jax.nn.one_hot(best, ne)  # (s, ne)
    out = jnp.zeros_like(xn)
    for e in range(ne):
        h = _gelu(xn @ params[f"l{l}.moe.e{e}.up"].T)
        ye = h @ params[f"l{l}.moe.e{e}.down"].T
        out = out + onehot[:, e : e + 1] * ye
    return gate * out


def seq_nll(params: dict, cfg: dict, tokens):
    """Mean next-token NLL of one sequence (S,) → scalar."""
    logits = forward(params, cfg, tokens)
    logp = jax.nn.log_softmax(logits[:-1], axis=-1)
    tgt = tokens[1:]
    return -jnp.mean(jnp.take_along_axis(logp, tgt[:, None], axis=-1))


def batch_nll(params: dict, cfg: dict, tokens_batch):
    """(B, S) → (B,) per-sequence mean NLL (the eval artifact)."""
    return jax.vmap(lambda t: seq_nll(params, cfg, t))(tokens_batch)


def batch_loss(params: dict, cfg: dict, tokens_batch):
    return jnp.mean(batch_nll(params, cfg, tokens_batch))


@functools.partial(jax.jit, static_argnames=("cfg_key",))
def _noop(cfg_key):  # pragma: no cover - placeholder to keep jit import hot
    return jnp.zeros(())
