"""`.tsr` bundle format tests (the Python half of the Rust↔Python contract)."""

import numpy as np
import pytest

from compile.tsr import load_tsr, save_tsr


def test_roundtrip(tmp_path):
    path = str(tmp_path / "b.tsr")
    tensors = {
        "w": np.arange(12, dtype=np.float32).reshape(3, 4),
        "bias": np.array([1.0, -2.0], dtype=np.float32),
    }
    save_tsr(path, tensors, {"step": 7})
    loaded, meta = load_tsr(path)
    np.testing.assert_array_equal(loaded["w"], tensors["w"])
    np.testing.assert_array_equal(loaded["bias"], tensors["bias"])
    assert meta["step"] == 7


def test_sorted_order_layout(tmp_path):
    """Offsets must follow sorted-name order (matching Rust's BTreeMap)."""
    path = str(tmp_path / "b.tsr")
    save_tsr(path, {"zz": np.ones(3), "aa": np.ones(2)})
    import json, struct

    with open(path, "rb") as f:
        f.read(4)
        (hlen,) = struct.unpack("<Q", f.read(8))
        header = json.loads(f.read(hlen))
    assert header["tensors"]["aa"]["offset"] == 0
    assert header["tensors"]["zz"]["offset"] == 2


def test_rejects_bad_magic(tmp_path):
    path = str(tmp_path / "bad.tsr")
    with open(path, "wb") as f:
        f.write(b"NOPE" + b"\0" * 16)
    with pytest.raises(ValueError):
        load_tsr(path)


def test_f64_input_downcast(tmp_path):
    path = str(tmp_path / "b.tsr")
    save_tsr(path, {"x": np.array([1.5, 2.5], dtype=np.float64)})
    loaded, _ = load_tsr(path)
    assert loaded["x"].dtype == np.float32
