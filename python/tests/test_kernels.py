"""Layer-1 correctness: every Pallas kernel vs its pure-jnp oracle,
swept over shapes/dtypes with hypothesis."""

import pytest

pytest.importorskip("jax", reason="JAX/Pallas not installed (bare runner)")
pytest.importorskip("hypothesis", reason="hypothesis not installed (bare runner)")

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import kernels
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def rand(key, *shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, dtype=jnp.float32)


# --------------------------------------------------------------------- #
# armor_matmul
# --------------------------------------------------------------------- #

@settings(max_examples=12, deadline=None)
@given(
    nbo=st.integers(1, 3),
    nbi=st.integers(1, 3),
    db=st.sampled_from([4, 8, 16]),
    seed=st.integers(0, 10_000),
)
def test_armor_matmul_matches_ref(nbo, nbi, db, seed):
    a = rand(seed, nbo, db, db)
    s = rand(seed + 1, nbo * db, nbi * db)
    b = rand(seed + 2, nbi, db, db)
    got = kernels.armor_matmul(a, s, b)
    want = ref.armor_matmul_ref(a, s, b)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_armor_matmul_identity_wrappers():
    db, nbo, nbi = 8, 2, 3
    eye = jnp.broadcast_to(jnp.eye(db), (nbo, db, db))
    eye_b = jnp.broadcast_to(jnp.eye(db), (nbi, db, db))
    s = rand(0, nbo * db, nbi * db)
    np.testing.assert_allclose(kernels.armor_matmul(eye, s, eye_b), s, rtol=1e-5)


def test_masked_armor_matmul_zeroes_masked():
    db = 4
    a = rand(1, 2, db, db)
    b = rand(2, 2, db, db)
    wp = rand(3, 8, 8)
    mask = jnp.zeros((8, 8), dtype=jnp.float32)
    out = kernels.masked_armor_matmul(a, wp, mask, b)
    np.testing.assert_allclose(out, jnp.zeros((8, 8)), atol=1e-7)


# --------------------------------------------------------------------- #
# proxy_loss
# --------------------------------------------------------------------- #

@settings(max_examples=12, deadline=None)
@given(
    rows=st.sampled_from([4, 8, 32, 33]),
    cols=st.sampled_from([8, 16, 64]),
    seed=st.integers(0, 10_000),
)
def test_proxy_loss_matches_ref(rows, cols, seed):
    wb = rand(seed, rows, cols)
    wh = rand(seed + 1, rows, cols)
    d = jnp.abs(rand(seed + 2, cols)) + 0.1
    got = kernels.proxy_loss(wb, wh, d)
    want = ref.proxy_loss_ref(wb, wh, d)
    np.testing.assert_allclose(got, want, rtol=2e-4)


def test_proxy_loss_zero_at_exact_match():
    w = rand(5, 16, 32)
    d = jnp.ones(32)
    assert float(kernels.proxy_loss(w, w, d)) == 0.0


def test_proxy_loss_weighting():
    wb = jnp.ones((1, 4))
    wh = jnp.zeros((1, 4))
    d = jnp.array([1.0, 2.0, 3.0, 4.0])
    assert float(kernels.proxy_loss(wb, wh, d)) == pytest.approx(10.0)


# --------------------------------------------------------------------- #
# mask_topk_nm
# --------------------------------------------------------------------- #

@settings(max_examples=12, deadline=None)
@given(
    rows=st.sampled_from([1, 4, 16]),
    groups=st.integers(1, 6),
    nm=st.sampled_from([(2, 4), (1, 4), (3, 4), (4, 8), (6, 8)]),
    seed=st.integers(0, 10_000),
)
def test_mask_topk_matches_ref(rows, groups, nm, seed):
    n, m = nm
    imp = jnp.abs(rand(seed, rows, groups * m))
    got = kernels.mask_topk_nm(imp, n, m)
    want = ref.mask_topk_nm_ref(imp, n, m)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # structural constraint
    per_group = np.asarray(got).reshape(rows, groups, m).sum(-1)
    assert (per_group == n).all()


def test_mask_topk_tie_break_prefers_lower_index():
    imp = jnp.array([[1.0, 1.0, 1.0, 1.0]])
    got = np.asarray(kernels.mask_topk_nm(imp, 2, 4))
    np.testing.assert_array_equal(got, [[1.0, 1.0, 0.0, 0.0]])


def test_mask_topk_keeps_largest():
    imp = jnp.array([[0.1, 0.9, 0.5, 0.2, 1.0, 0.0, 0.3, 0.7]])
    got = np.asarray(kernels.mask_topk_nm(imp, 2, 4))
    np.testing.assert_array_equal(got, [[0, 1, 1, 0, 1, 0, 0, 1]])


# --------------------------------------------------------------------- #
# sparse_group_ls
# --------------------------------------------------------------------- #

@settings(max_examples=10, deadline=None)
@given(
    nb=st.integers(1, 4),
    db=st.sampled_from([4, 8, 16]),
    seed=st.integers(0, 10_000),
)
def test_sparse_group_ls_matches_ref(nb, db, seed):
    m = 4
    e = rand(seed, nb, db, db)
    a_cols = rand(seed + 1, nb, db)
    u = rand(seed + 2, nb, m, db)
    d = jnp.abs(rand(seed + 3, nb, db)) + 0.1
    cur = rand(seed + 4, nb, m)
    gains, vals = kernels.sparse_group_ls(e, a_cols, u, d, cur, m=m)

    combos = jnp.array([(i, j) for i in range(m) for j in range(i + 1, m)])
    for blk in range(nb):
        best_ref, vals_ref, gains_ref = ref.group_ls_ref(
            e[blk], a_cols[blk], u[blk], d[blk], cur[blk], combos
        )
        np.testing.assert_allclose(gains[blk], gains_ref, rtol=1e-3, atol=1e-3)
        best_kernel = int(jnp.argmax(gains[blk]))
        # the winning mask's values must match the oracle's LS solution
        np.testing.assert_allclose(
            vals[blk, best_kernel], np.asarray(vals_ref), rtol=1e-3, atol=1e-3
        )


def test_sparse_group_ls_gain_is_loss_reduction():
    """Applying the winning (mask, values) must reduce the block proxy loss
    by exactly the reported gain (Eq. 8)."""
    db, m = 8, 4
    key = 77
    e = rand(key, 1, db, db)
    a_col = rand(key + 1, 1, db)
    u = rand(key + 2, 1, m, db)
    d = jnp.abs(rand(key + 3, 1, db)) + 0.1
    cur = jnp.zeros((1, m))  # group currently zeroed ⇒ ΔW = E
    gains, vals = kernels.sparse_group_ls(e, a_col, u, d, cur, m=m)
    best = int(jnp.argmax(gains[0]))
    combos = [(i, j) for i in range(m) for j in range(i + 1, m)]
    i1, i2 = combos[best]
    w = vals[0, best]
    # ΔW = E; new residual = E − a (w0·u_{i1} + w1·u_{i2})
    contrib = jnp.outer(a_col[0], w[0] * u[0, i1] + w[1] * u[0, i2])
    before = jnp.sum(e[0] ** 2 * d[0][None, :])
    after = jnp.sum((e[0] - contrib) ** 2 * d[0][None, :])
    np.testing.assert_allclose(before - after, gains[0, best], rtol=1e-3)


# --------------------------------------------------------------------- #
# attn_decode
# --------------------------------------------------------------------- #

@settings(max_examples=12, deadline=None)
@given(
    bsz=st.integers(1, 4),
    n_heads=st.sampled_from([1, 2, 4]),
    head_dim=st.sampled_from([4, 8, 16]),
    max_seq=st.sampled_from([8, 16]),
    seed=st.integers(0, 10_000),
)
def test_attn_decode_matches_ref(bsz, n_heads, head_dim, max_seq, seed):
    q = rand(seed, bsz, n_heads, head_dim)
    k = rand(seed + 1, bsz, n_heads, max_seq, head_dim)
    v = rand(seed + 2, bsz, n_heads, max_seq, head_dim)
    # ragged: every sequence gets its own length in [1, max_seq]
    lens = jax.random.randint(jax.random.PRNGKey(seed + 3), (bsz,), 1, max_seq + 1)
    got = kernels.attn_decode(q, k, v, lens)
    want = ref.attn_decode_ref(q, k, v, lens)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_attn_decode_ignores_rows_past_length():
    """Positions >= seq_lens[b] must not influence the output — the ragged
    mask is the kernel's slice-at-n_ctx equivalent."""
    bsz, n_heads, head_dim, max_seq = 2, 2, 8, 16
    q = rand(0, bsz, n_heads, head_dim)
    k = rand(1, bsz, n_heads, max_seq, head_dim)
    v = rand(2, bsz, n_heads, max_seq, head_dim)
    lens = jnp.array([5, 11], dtype=jnp.int32)
    base = kernels.attn_decode(q, k, v, lens)
    # scribble over the masked tail
    k2 = k.at[0, :, 5:].set(1e6).at[1, :, 11:].set(-1e6)
    v2 = v.at[0, :, 5:].set(1e6).at[1, :, 11:].set(-1e6)
    got = kernels.attn_decode(q, k2, v2, lens)
    np.testing.assert_allclose(got, base, rtol=1e-6, atol=1e-6)


def test_attn_decode_single_position_returns_value_row():
    """With one cached position the softmax weight is 1: output == V[:, :, 0]."""
    q = rand(3, 3, 2, 8)
    k = rand(4, 3, 2, 4, 8)
    v = rand(5, 3, 2, 4, 8)
    lens = jnp.ones((3,), dtype=jnp.int32)
    got = kernels.attn_decode(q, k, v, lens)
    np.testing.assert_allclose(got, v[:, :, 0], rtol=1e-5, atol=1e-6)


# --------------------------------------------------------------------- #
# attn_decode_paged
# --------------------------------------------------------------------- #

def _assemble_panels(pages, table, max_seq):
    """Flatten (n_pool, h, page, d) pool + (b, n_chain) tables into the
    contiguous (b, h, max_seq, d) panels the non-paged reference reads."""
    gathered = pages[table]  # (b, n_chain, h, page, d)
    flat = jnp.moveaxis(gathered, 2, 1).reshape(
        table.shape[0], pages.shape[1], -1, pages.shape[3]
    )
    return flat[:, :, :max_seq]


@settings(max_examples=12, deadline=None)
@given(
    bsz=st.integers(1, 4),
    n_heads=st.sampled_from([1, 2]),
    head_dim=st.sampled_from([4, 8]),
    page=st.sampled_from([1, 2, 4]),
    n_chain=st.integers(1, 4),
    seed=st.integers(0, 10_000),
)
def test_attn_decode_paged_matches_contiguous_ref(bsz, n_heads, head_dim, page, n_chain, seed):
    """Paging is an addressing change only: gathering each sequence's chain
    from the pool must equal the contiguous reference on the assembled
    panels, for random page sizes, chain lengths, and ragged seq_lens."""
    n_pool = bsz * n_chain  # worst case: no sharing
    q = rand(seed, bsz, n_heads, head_dim)
    k_pages = rand(seed + 1, n_pool, n_heads, page, head_dim)
    v_pages = rand(seed + 2, n_pool, n_heads, page, head_dim)
    keys = jax.random.split(jax.random.PRNGKey(seed + 3), 2)
    table = jax.random.randint(keys[0], (bsz, n_chain), 0, n_pool)
    max_seq = n_chain * page
    lens = jax.random.randint(keys[1], (bsz,), 1, max_seq + 1)
    got = kernels.attn_decode_paged(q, k_pages, v_pages, table, lens)
    want = ref.attn_decode_ref(
        q,
        _assemble_panels(k_pages, table, max_seq),
        _assemble_panels(v_pages, table, max_seq),
        lens,
    )
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_attn_decode_paged_shared_prefix_pages():
    """Two sequences whose tables point at the same prefix pages attend over
    identical values there — the KvPool refcount-sharing layout — and only
    their divergent tail pages differ."""
    n_heads, head_dim, page = 2, 8, 4
    q = rand(0, 2, n_heads, head_dim)
    q = q.at[1].set(q[0])  # same query → outputs differ only via K/V
    k_pages = rand(1, 4, n_heads, page, head_dim)
    v_pages = rand(2, 4, n_heads, page, head_dim)
    # chains: seq0 = [pool0, pool1, pool2], seq1 = [pool0, pool1, pool3]
    table = jnp.array([[0, 1, 2], [0, 1, 3]], dtype=jnp.int32)
    # within the shared prefix only → identical outputs
    lens = jnp.array([8, 8], dtype=jnp.int32)
    out = kernels.attn_decode_paged(q, k_pages, v_pages, table, lens)
    np.testing.assert_allclose(out[0], out[1], rtol=1e-6, atol=1e-6)
    # past the divergence point → outputs must differ
    lens = jnp.array([12, 12], dtype=jnp.int32)
    out = kernels.attn_decode_paged(q, k_pages, v_pages, table, lens)
    assert not np.allclose(out[0], out[1], rtol=1e-3, atol=1e-3)


def test_attn_decode_paged_ignores_pages_past_length():
    """Ragged tail masking: positions >= seq_lens never contribute, even
    when the table's tail entries alias arbitrary (scribbled) pool pages."""
    n_heads, head_dim, page = 2, 8, 4
    q = rand(0, 1, n_heads, head_dim)
    k_pages = rand(1, 3, n_heads, page, head_dim)
    v_pages = rand(2, 3, n_heads, page, head_dim)
    table = jnp.array([[0, 1, 2]], dtype=jnp.int32)
    lens = jnp.array([6], dtype=jnp.int32)  # mid-page-1: rest is masked
    base = kernels.attn_decode_paged(q, k_pages, v_pages, table, lens)
    k2 = k_pages.at[1, :, 2:].set(1e6).at[2].set(-1e6)
    v2 = v_pages.at[1, :, 2:].set(1e6).at[2].set(-1e6)
    got = kernels.attn_decode_paged(q, k2, v2, table, lens)
    np.testing.assert_allclose(got, base, rtol=1e-6, atol=1e-6)
