"""Layer-1 correctness: every Pallas kernel vs its pure-jnp oracle,
swept over shapes/dtypes with hypothesis."""

import pytest

pytest.importorskip("jax", reason="JAX/Pallas not installed (bare runner)")
pytest.importorskip("hypothesis", reason="hypothesis not installed (bare runner)")

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import kernels
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def rand(key, *shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, dtype=jnp.float32)


# --------------------------------------------------------------------- #
# armor_matmul
# --------------------------------------------------------------------- #

@settings(max_examples=12, deadline=None)
@given(
    nbo=st.integers(1, 3),
    nbi=st.integers(1, 3),
    db=st.sampled_from([4, 8, 16]),
    seed=st.integers(0, 10_000),
)
def test_armor_matmul_matches_ref(nbo, nbi, db, seed):
    a = rand(seed, nbo, db, db)
    s = rand(seed + 1, nbo * db, nbi * db)
    b = rand(seed + 2, nbi, db, db)
    got = kernels.armor_matmul(a, s, b)
    want = ref.armor_matmul_ref(a, s, b)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_armor_matmul_identity_wrappers():
    db, nbo, nbi = 8, 2, 3
    eye = jnp.broadcast_to(jnp.eye(db), (nbo, db, db))
    eye_b = jnp.broadcast_to(jnp.eye(db), (nbi, db, db))
    s = rand(0, nbo * db, nbi * db)
    np.testing.assert_allclose(kernels.armor_matmul(eye, s, eye_b), s, rtol=1e-5)


def test_masked_armor_matmul_zeroes_masked():
    db = 4
    a = rand(1, 2, db, db)
    b = rand(2, 2, db, db)
    wp = rand(3, 8, 8)
    mask = jnp.zeros((8, 8), dtype=jnp.float32)
    out = kernels.masked_armor_matmul(a, wp, mask, b)
    np.testing.assert_allclose(out, jnp.zeros((8, 8)), atol=1e-7)


# --------------------------------------------------------------------- #
# proxy_loss
# --------------------------------------------------------------------- #

@settings(max_examples=12, deadline=None)
@given(
    rows=st.sampled_from([4, 8, 32, 33]),
    cols=st.sampled_from([8, 16, 64]),
    seed=st.integers(0, 10_000),
)
def test_proxy_loss_matches_ref(rows, cols, seed):
    wb = rand(seed, rows, cols)
    wh = rand(seed + 1, rows, cols)
    d = jnp.abs(rand(seed + 2, cols)) + 0.1
    got = kernels.proxy_loss(wb, wh, d)
    want = ref.proxy_loss_ref(wb, wh, d)
    np.testing.assert_allclose(got, want, rtol=2e-4)


def test_proxy_loss_zero_at_exact_match():
    w = rand(5, 16, 32)
    d = jnp.ones(32)
    assert float(kernels.proxy_loss(w, w, d)) == 0.0


def test_proxy_loss_weighting():
    wb = jnp.ones((1, 4))
    wh = jnp.zeros((1, 4))
    d = jnp.array([1.0, 2.0, 3.0, 4.0])
    assert float(kernels.proxy_loss(wb, wh, d)) == pytest.approx(10.0)


# --------------------------------------------------------------------- #
# mask_topk_nm
# --------------------------------------------------------------------- #

@settings(max_examples=12, deadline=None)
@given(
    rows=st.sampled_from([1, 4, 16]),
    groups=st.integers(1, 6),
    nm=st.sampled_from([(2, 4), (1, 4), (3, 4), (4, 8), (6, 8)]),
    seed=st.integers(0, 10_000),
)
def test_mask_topk_matches_ref(rows, groups, nm, seed):
    n, m = nm
    imp = jnp.abs(rand(seed, rows, groups * m))
    got = kernels.mask_topk_nm(imp, n, m)
    want = ref.mask_topk_nm_ref(imp, n, m)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # structural constraint
    per_group = np.asarray(got).reshape(rows, groups, m).sum(-1)
    assert (per_group == n).all()


def test_mask_topk_tie_break_prefers_lower_index():
    imp = jnp.array([[1.0, 1.0, 1.0, 1.0]])
    got = np.asarray(kernels.mask_topk_nm(imp, 2, 4))
    np.testing.assert_array_equal(got, [[1.0, 1.0, 0.0, 0.0]])


def test_mask_topk_keeps_largest():
    imp = jnp.array([[0.1, 0.9, 0.5, 0.2, 1.0, 0.0, 0.3, 0.7]])
    got = np.asarray(kernels.mask_topk_nm(imp, 2, 4))
    np.testing.assert_array_equal(got, [[0, 1, 1, 0, 1, 0, 0, 1]])


# --------------------------------------------------------------------- #
# sparse_group_ls
# --------------------------------------------------------------------- #

@settings(max_examples=10, deadline=None)
@given(
    nb=st.integers(1, 4),
    db=st.sampled_from([4, 8, 16]),
    seed=st.integers(0, 10_000),
)
def test_sparse_group_ls_matches_ref(nb, db, seed):
    m = 4
    e = rand(seed, nb, db, db)
    a_cols = rand(seed + 1, nb, db)
    u = rand(seed + 2, nb, m, db)
    d = jnp.abs(rand(seed + 3, nb, db)) + 0.1
    cur = rand(seed + 4, nb, m)
    gains, vals = kernels.sparse_group_ls(e, a_cols, u, d, cur, m=m)

    combos = jnp.array([(i, j) for i in range(m) for j in range(i + 1, m)])
    for blk in range(nb):
        best_ref, vals_ref, gains_ref = ref.group_ls_ref(
            e[blk], a_cols[blk], u[blk], d[blk], cur[blk], combos
        )
        np.testing.assert_allclose(gains[blk], gains_ref, rtol=1e-3, atol=1e-3)
        best_kernel = int(jnp.argmax(gains[blk]))
        # the winning mask's values must match the oracle's LS solution
        np.testing.assert_allclose(
            vals[blk, best_kernel], np.asarray(vals_ref), rtol=1e-3, atol=1e-3
        )


def test_sparse_group_ls_gain_is_loss_reduction():
    """Applying the winning (mask, values) must reduce the block proxy loss
    by exactly the reported gain (Eq. 8)."""
    db, m = 8, 4
    key = 77
    e = rand(key, 1, db, db)
    a_col = rand(key + 1, 1, db)
    u = rand(key + 2, 1, m, db)
    d = jnp.abs(rand(key + 3, 1, db)) + 0.1
    cur = jnp.zeros((1, m))  # group currently zeroed ⇒ ΔW = E
    gains, vals = kernels.sparse_group_ls(e, a_col, u, d, cur, m=m)
    best = int(jnp.argmax(gains[0]))
    combos = [(i, j) for i in range(m) for j in range(i + 1, m)]
    i1, i2 = combos[best]
    w = vals[0, best]
    # ΔW = E; new residual = E − a (w0·u_{i1} + w1·u_{i2})
    contrib = jnp.outer(a_col[0], w[0] * u[0, i1] + w[1] * u[0, i2])
    before = jnp.sum(e[0] ** 2 * d[0][None, :])
    after = jnp.sum((e[0] - contrib) ** 2 * d[0][None, :])
    np.testing.assert_allclose(before - after, gains[0, best], rtol=1e-3)


# --------------------------------------------------------------------- #
# attn_decode
# --------------------------------------------------------------------- #

@settings(max_examples=12, deadline=None)
@given(
    bsz=st.integers(1, 4),
    n_heads=st.sampled_from([1, 2, 4]),
    head_dim=st.sampled_from([4, 8, 16]),
    max_seq=st.sampled_from([8, 16]),
    seed=st.integers(0, 10_000),
)
def test_attn_decode_matches_ref(bsz, n_heads, head_dim, max_seq, seed):
    q = rand(seed, bsz, n_heads, head_dim)
    k = rand(seed + 1, bsz, n_heads, max_seq, head_dim)
    v = rand(seed + 2, bsz, n_heads, max_seq, head_dim)
    # ragged: every sequence gets its own length in [1, max_seq]
    lens = jax.random.randint(jax.random.PRNGKey(seed + 3), (bsz,), 1, max_seq + 1)
    got = kernels.attn_decode(q, k, v, lens)
    want = ref.attn_decode_ref(q, k, v, lens)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_attn_decode_ignores_rows_past_length():
    """Positions >= seq_lens[b] must not influence the output — the ragged
    mask is the kernel's slice-at-n_ctx equivalent."""
    bsz, n_heads, head_dim, max_seq = 2, 2, 8, 16
    q = rand(0, bsz, n_heads, head_dim)
    k = rand(1, bsz, n_heads, max_seq, head_dim)
    v = rand(2, bsz, n_heads, max_seq, head_dim)
    lens = jnp.array([5, 11], dtype=jnp.int32)
    base = kernels.attn_decode(q, k, v, lens)
    # scribble over the masked tail
    k2 = k.at[0, :, 5:].set(1e6).at[1, :, 11:].set(-1e6)
    v2 = v.at[0, :, 5:].set(1e6).at[1, :, 11:].set(-1e6)
    got = kernels.attn_decode(q, k2, v2, lens)
    np.testing.assert_allclose(got, base, rtol=1e-6, atol=1e-6)


def test_attn_decode_single_position_returns_value_row():
    """With one cached position the softmax weight is 1: output == V[:, :, 0]."""
    q = rand(3, 3, 2, 8)
    k = rand(4, 3, 2, 4, 8)
    v = rand(5, 3, 2, 4, 8)
    lens = jnp.ones((3,), dtype=jnp.int32)
    got = kernels.attn_decode(q, k, v, lens)
    np.testing.assert_allclose(got, v[:, :, 0], rtol=1e-5, atol=1e-6)


# --------------------------------------------------------------------- #
# sparse_matmul_q8
# --------------------------------------------------------------------- #

def _quantize_packed(w_packed, group):
    """Symmetric int8 quantization of a packed value plane, matching
    `sparsity::q8_quantize`: one scale per `group` packed values per row
    (scale = group_max / 127; all-zero groups get scale 0)."""
    w = np.asarray(w_packed, dtype=np.float32)
    rows, n_packed = w.shape
    n_groups = max(-(-n_packed // group), 1)
    codes = np.zeros((rows, n_packed), dtype=np.int8)
    scales = np.zeros((rows, n_groups), dtype=np.float32)
    for g in range(n_groups):
        seg = w[:, g * group : min((g + 1) * group, n_packed)]
        if seg.shape[1] == 0:
            continue
        max_abs = np.abs(seg).max(axis=1)
        s = np.where(max_abs > 0, max_abs / 127.0, 0.0)
        scales[:, g] = s
        q = np.divide(seg, s[:, None], out=np.zeros_like(seg), where=s[:, None] > 0)
        codes[:, g * group : g * group + seg.shape[1]] = np.clip(
            np.rint(q), -127, 127
        ).astype(np.int8)
    return jnp.asarray(codes), jnp.asarray(scales)


def _random_24_columns(rng, rows, cols):
    """Random 2:4 metadata as absolute column indices: two distinct kept
    positions per group of 4 columns, ascending within the group."""
    g = cols // 4
    pairs = np.array([(i, j) for i in range(4) for j in range(i + 1, 4)])
    sel = pairs[rng.integers(0, len(pairs), size=(rows, g))]  # (rows, g, 2)
    base = (np.arange(g) * 4)[None, :, None]
    return jnp.asarray((sel + base).reshape(rows, 2 * g).astype(np.int32))


@settings(max_examples=12, deadline=None)
@given(
    rows=st.sampled_from([1, 4, 9, 16]),
    g=st.integers(1, 6),
    batch=st.sampled_from([1, 3, 8]),
    group=st.sampled_from([2, 4, 16]),
    seed=st.integers(0, 10_000),
)
def test_sparse_matmul_q8_matches_ref(rows, g, batch, group, seed):
    """The fused dequant kernel equals the dequantize-then-dense oracle for
    random shapes, metadata, scale-group sizes (ragged last group), and
    batch widths."""
    cols = 4 * g
    rng = np.random.default_rng(seed)
    col_idx = _random_24_columns(rng, rows, cols)
    packed = rand(seed + 1, rows, 2 * g)
    codes, scales = _quantize_packed(packed, group)
    x = rand(seed + 2, cols, batch)
    got = kernels.sparse_matmul_q8(codes, col_idx, scales, x, group=group)
    want = ref.sparse_matmul_q8_ref(codes, col_idx, scales, x, group)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_sparse_matmul_q8_close_to_f32_within_quant_bound():
    """Quantize a real f32 value plane: the q8 product stays within the
    per-value error envelope (scale/2 <= wmax/254 per weight, summed over
    each activation column's L1 mass)."""
    rows, g, batch, group = 8, 8, 5, 4
    cols = 4 * g
    rng = np.random.default_rng(7)
    col_idx = _random_24_columns(rng, rows, cols)
    packed = rand(8, rows, 2 * g)
    codes, scales = _quantize_packed(packed, group)
    x = rand(9, cols, batch)
    got = np.asarray(kernels.sparse_matmul_q8(codes, col_idx, scales, x, group=group))
    # f32 reference on the *original* (unquantized) values
    dense = np.zeros((rows, cols), dtype=np.float32)
    np.put_along_axis(dense, np.asarray(col_idx), np.asarray(packed), axis=1)
    want = dense @ np.asarray(x)
    wmax = np.abs(np.asarray(packed)).max()
    for j in range(batch):
        tol = wmax / 254.0 * np.abs(np.asarray(x)[:, j]).sum() * 1.5 + 1e-5
        np.testing.assert_allclose(got[:, j], want[:, j], atol=tol)


def test_sparse_matmul_q8_zero_groups_contribute_nothing():
    """An all-zero scale group (scale 0) must contribute exactly 0, not NaN."""
    rows, g, group = 2, 2, 2
    cols = 4 * g
    rng = np.random.default_rng(11)
    col_idx = _random_24_columns(rng, rows, cols)
    packed = jnp.zeros((rows, 2 * g))
    codes, scales = _quantize_packed(packed, group)
    x = rand(12, cols, 3)
    out = np.asarray(kernels.sparse_matmul_q8(codes, col_idx, scales, x, group=group))
    np.testing.assert_array_equal(out, np.zeros((rows, 3), dtype=np.float32))


# --------------------------------------------------------------------- #
# attn_decode_paged
# --------------------------------------------------------------------- #

def _assemble_panels(pages, table, max_seq):
    """Flatten (n_pool, h, page, d) pool + (b, n_chain) tables into the
    contiguous (b, h, max_seq, d) panels the non-paged reference reads."""
    gathered = pages[table]  # (b, n_chain, h, page, d)
    flat = jnp.moveaxis(gathered, 2, 1).reshape(
        table.shape[0], pages.shape[1], -1, pages.shape[3]
    )
    return flat[:, :, :max_seq]


@settings(max_examples=12, deadline=None)
@given(
    bsz=st.integers(1, 4),
    n_heads=st.sampled_from([1, 2]),
    head_dim=st.sampled_from([4, 8]),
    page=st.sampled_from([1, 2, 4]),
    n_chain=st.integers(1, 4),
    seed=st.integers(0, 10_000),
)
def test_attn_decode_paged_matches_contiguous_ref(bsz, n_heads, head_dim, page, n_chain, seed):
    """Paging is an addressing change only: gathering each sequence's chain
    from the pool must equal the contiguous reference on the assembled
    panels, for random page sizes, chain lengths, and ragged seq_lens."""
    n_pool = bsz * n_chain  # worst case: no sharing
    q = rand(seed, bsz, n_heads, head_dim)
    k_pages = rand(seed + 1, n_pool, n_heads, page, head_dim)
    v_pages = rand(seed + 2, n_pool, n_heads, page, head_dim)
    keys = jax.random.split(jax.random.PRNGKey(seed + 3), 2)
    table = jax.random.randint(keys[0], (bsz, n_chain), 0, n_pool)
    max_seq = n_chain * page
    lens = jax.random.randint(keys[1], (bsz,), 1, max_seq + 1)
    got = kernels.attn_decode_paged(q, k_pages, v_pages, table, lens)
    want = ref.attn_decode_ref(
        q,
        _assemble_panels(k_pages, table, max_seq),
        _assemble_panels(v_pages, table, max_seq),
        lens,
    )
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_attn_decode_paged_shared_prefix_pages():
    """Two sequences whose tables point at the same prefix pages attend over
    identical values there — the KvPool refcount-sharing layout — and only
    their divergent tail pages differ."""
    n_heads, head_dim, page = 2, 8, 4
    q = rand(0, 2, n_heads, head_dim)
    q = q.at[1].set(q[0])  # same query → outputs differ only via K/V
    k_pages = rand(1, 4, n_heads, page, head_dim)
    v_pages = rand(2, 4, n_heads, page, head_dim)
    # chains: seq0 = [pool0, pool1, pool2], seq1 = [pool0, pool1, pool3]
    table = jnp.array([[0, 1, 2], [0, 1, 3]], dtype=jnp.int32)
    # within the shared prefix only → identical outputs
    lens = jnp.array([8, 8], dtype=jnp.int32)
    out = kernels.attn_decode_paged(q, k_pages, v_pages, table, lens)
    np.testing.assert_allclose(out[0], out[1], rtol=1e-6, atol=1e-6)
    # past the divergence point → outputs must differ
    lens = jnp.array([12, 12], dtype=jnp.int32)
    out = kernels.attn_decode_paged(q, k_pages, v_pages, table, lens)
    assert not np.allclose(out[0], out[1], rtol=1e-3, atol=1e-3)


def test_attn_decode_paged_ignores_pages_past_length():
    """Ragged tail masking: positions >= seq_lens never contribute, even
    when the table's tail entries alias arbitrary (scribbled) pool pages."""
    n_heads, head_dim, page = 2, 8, 4
    q = rand(0, 1, n_heads, head_dim)
    k_pages = rand(1, 3, n_heads, page, head_dim)
    v_pages = rand(2, 3, n_heads, page, head_dim)
    table = jnp.array([[0, 1, 2]], dtype=jnp.int32)
    lens = jnp.array([6], dtype=jnp.int32)  # mid-page-1: rest is masked
    base = kernels.attn_decode_paged(q, k_pages, v_pages, table, lens)
    k2 = k_pages.at[1, :, 2:].set(1e6).at[2].set(-1e6)
    v2 = v_pages.at[1, :, 2:].set(1e6).at[2].set(-1e6)
    got = kernels.attn_decode_paged(q, k2, v2, table, lens)
    np.testing.assert_allclose(got, base, rtol=1e-6, atol=1e-6)


# --------------------------------------------------------------------- #
# attn_decode_paged_q8
# --------------------------------------------------------------------- #

def _quantize_pages(pages):
    """Per-(page, head, position) symmetric int8 quantization of an f32
    page pool — the `serve::KvPool` q8 append layout: one scale per
    head-slice, fixed when the position is written."""
    p = np.asarray(pages, dtype=np.float32)
    max_abs = np.abs(p).max(axis=-1)
    scales = np.where(max_abs > 0, max_abs / 127.0, 0.0).astype(np.float32)
    q = np.divide(p, scales[..., None], out=np.zeros_like(p), where=scales[..., None] > 0)
    codes = np.clip(np.rint(q), -127, 127).astype(np.int8)
    return jnp.asarray(codes), jnp.asarray(scales)


@settings(max_examples=12, deadline=None)
@given(
    bsz=st.integers(1, 4),
    n_heads=st.sampled_from([1, 2]),
    head_dim=st.sampled_from([4, 8]),
    page=st.sampled_from([1, 2, 4]),
    n_chain=st.integers(1, 4),
    seed=st.integers(0, 10_000),
)
def test_attn_decode_paged_q8_matches_ref(bsz, n_heads, head_dim, page, n_chain, seed):
    """The q8 paged kernel equals the dequantize-then-attend oracle for
    random page sizes, chain lengths, shared tables, and ragged lens —
    quantization is an addressing-plus-dtype change, never an arithmetic
    one."""
    n_pool = bsz * n_chain
    q = rand(seed, bsz, n_heads, head_dim)
    k_codes, k_sc = _quantize_pages(rand(seed + 1, n_pool, n_heads, page, head_dim))
    v_codes, v_sc = _quantize_pages(rand(seed + 2, n_pool, n_heads, page, head_dim))
    keys = jax.random.split(jax.random.PRNGKey(seed + 3), 2)
    table = jax.random.randint(keys[0], (bsz, n_chain), 0, n_pool)
    lens = jax.random.randint(keys[1], (bsz,), 1, n_chain * page + 1)
    got = kernels.attn_decode_paged_q8(q, k_codes, v_codes, k_sc, v_sc, table, lens)
    want = ref.attn_decode_paged_q8_ref(q, k_codes, v_codes, k_sc, v_sc, table, lens)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_attn_decode_paged_q8_close_to_f32_attention():
    """Quantizing real pages perturbs the attention output only within the
    int8 error envelope of the f32 paged kernel on the same values."""
    bsz, n_heads, head_dim, page, n_chain = 2, 2, 8, 4, 3
    n_pool = bsz * n_chain
    q = rand(20, bsz, n_heads, head_dim)
    k_pages = rand(21, n_pool, n_heads, page, head_dim)
    v_pages = rand(22, n_pool, n_heads, page, head_dim)
    table = jnp.arange(n_pool, dtype=jnp.int32).reshape(bsz, n_chain)
    lens = jnp.array([7, 12], dtype=jnp.int32)
    f32_out = np.asarray(kernels.attn_decode_paged(q, k_pages, v_pages, table, lens))
    k_codes, k_sc = _quantize_pages(k_pages)
    v_codes, v_sc = _quantize_pages(v_pages)
    q8_out = np.asarray(
        kernels.attn_decode_paged_q8(q, k_codes, v_codes, k_sc, v_sc, table, lens)
    )
    # score shift <= ||q||_1 * kmax/254 / sqrt(hd) per position; softmax
    # weights move by at most e^{2D}; V rows carry their own vmax/254
    kmax = float(np.abs(np.asarray(k_pages)).max())
    vmax = float(np.abs(np.asarray(v_pages)).max())
    q_l1 = float(np.abs(np.asarray(q)).sum(axis=-1).max())
    d_max = q_l1 * (kmax / 254.0) / head_dim**0.5
    tol = (np.exp(2 * d_max) - 1.0) * vmax + vmax / 254.0 + 1e-4
    np.testing.assert_allclose(q8_out, f32_out, atol=tol)


def test_attn_decode_paged_q8_shared_prefix_scales_travel_with_pages():
    """Two chains sharing prefix pages share codes AND scales — identical
    outputs inside the shared span, divergent past it (the CoW contract the
    Rust pool enforces)."""
    n_heads, head_dim, page = 2, 8, 4
    q = rand(30, 2, n_heads, head_dim)
    q = q.at[1].set(q[0])
    k_codes, k_sc = _quantize_pages(rand(31, 4, n_heads, page, head_dim))
    v_codes, v_sc = _quantize_pages(rand(32, 4, n_heads, page, head_dim))
    table = jnp.array([[0, 1, 2], [0, 1, 3]], dtype=jnp.int32)
    lens = jnp.array([8, 8], dtype=jnp.int32)
    out = kernels.attn_decode_paged_q8(q, k_codes, v_codes, k_sc, v_sc, table, lens)
    np.testing.assert_allclose(out[0], out[1], rtol=1e-6, atol=1e-6)
    lens = jnp.array([12, 12], dtype=jnp.int32)
    out = kernels.attn_decode_paged_q8(q, k_codes, v_codes, k_sc, v_sc, table, lens)
    assert not np.allclose(out[0], out[1], rtol=1e-3, atol=1e-3)
