"""Pytest wiring for the build-time Python layer.

Makes `python -m pytest python/tests -q` work from the repo root: the
`compile` package lives in `python/`, which is not on `sys.path` when the
rootdir is the repo root, so prepend it here. Individual test modules
skip-guard their JAX/Pallas and hypothesis imports (`pytest.importorskip`)
so the suite passes on bare runners that carry neither.
"""

import os
import sys

sys.path.insert(0, os.path.abspath(os.path.join(os.path.dirname(__file__), os.pardir)))
