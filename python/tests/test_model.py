"""Layer-2 model tests: shapes, causality, trainability, MoE routing."""

import pytest

pytest.importorskip("jax", reason="JAX/Pallas not installed (bare runner)")

import jax
import jax.numpy as jnp
import numpy as np

from compile import model as M

CFG = {"vocab": 64, "d_model": 32, "n_layers": 2, "n_heads": 2, "d_ff": 64, "max_seq": 32}
MOE_CFG = {**CFG, "moe": {"n_experts": 2, "top_k": 1}}


def toks(key, n, vocab=64):
    return jax.random.randint(jax.random.PRNGKey(key), (n,), 0, vocab)


def test_forward_shapes():
    p = M.init_params(CFG, jax.random.PRNGKey(0))
    logits = M.forward(p, CFG, toks(1, 16))
    assert logits.shape == (16, 64)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_causality():
    p = M.init_params(CFG, jax.random.PRNGKey(0))
    a = np.asarray(toks(2, 12))
    b = a.copy()
    b[10] = (b[10] + 1) % 64
    la = M.forward(p, CFG, jnp.asarray(a))
    lb = M.forward(p, CFG, jnp.asarray(b))
    np.testing.assert_allclose(la[:10], lb[:10], atol=1e-5)
    assert not np.allclose(la[10], lb[10], atol=1e-5)


def test_untrained_nll_near_uniform():
    p = M.init_params(CFG, jax.random.PRNGKey(0))
    nll = float(M.seq_nll(p, CFG, toks(3, 32)))
    assert abs(nll - np.log(64)) < 1.0


def test_short_training_reduces_loss():
    p = M.init_params(CFG, jax.random.PRNGKey(1))
    # learnable data: fixed repeating pattern
    seq = jnp.asarray(np.tile(np.arange(8), 8)[:32])[None].repeat(4, axis=0)
    loss_grad = jax.jit(jax.value_and_grad(lambda p: M.batch_loss(p, CFG, seq)))
    l0, _ = loss_grad(p)
    # 60 steps: 30 landed within noise of the 0.7 threshold (0.704·l0 on
    # jax 0.4.37), making the assertion version/seed-brittle
    for _ in range(60):
        loss, g = loss_grad(p)
        p = {k: v - 0.01 * g[k] for k, v in p.items()}
    assert float(loss) < 0.7 * float(l0)


def test_moe_forward_and_gating():
    p = M.init_params(MOE_CFG, jax.random.PRNGKey(2))
    logits = M.forward(p, MOE_CFG, toks(4, 16))
    assert logits.shape == (16, 64)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_batch_nll_matches_seq_nll():
    p = M.init_params(CFG, jax.random.PRNGKey(3))
    batch = jnp.stack([toks(5, 16), toks(6, 16)])
    got = M.batch_nll(p, CFG, batch)
    want = jnp.stack([M.seq_nll(p, CFG, batch[0]), M.seq_nll(p, CFG, batch[1])])
    np.testing.assert_allclose(got, want, rtol=1e-5)


def test_gelu_matches_rust_constants():
    # same tanh approximation as rust gelu_inplace
    x = jnp.linspace(-3, 3, 13)
    c = 0.7978845608
    want = 0.5 * x * (1 + jnp.tanh(c * (x + 0.044715 * x**3)))
    np.testing.assert_allclose(M._gelu(x), want, rtol=1e-6)
