"""AOT lowering smoke tests: each artifact family lowers to valid HLO text
containing an entry computation, and executes correctly via jax before
export (the numerics the Rust runtime will reproduce)."""

import pytest

pytest.importorskip("jax", reason="JAX/Pallas not installed (bare runner)")

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot, model as M

CFG = {"vocab": 64, "d_model": 16, "n_layers": 1, "n_heads": 2, "d_ff": 32, "max_seq": 16}


def test_cont_steps_lowers_to_hlo_text():
    lowered, ins, outs = aot.lower_cont_steps(16, 32, 8)
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text
    assert len(ins) == 14
    assert len(outs) == 11
    assert outs[-1] == []  # scalar loss


def test_proxy_loss_artifact_numerics():
    lowered, _, _ = aot.lower_proxy_loss(8, 16, 8)
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text
    # executing the jitted fn gives the jnp reference value
    key = jax.random.PRNGKey(0)
    a = jnp.broadcast_to(jnp.eye(8), (1, 8, 8))
    b = jnp.broadcast_to(jnp.eye(8), (2, 8, 8))
    wp = jax.random.normal(key, (8, 16))
    mask = jnp.ones((8, 16))
    w_bar = jnp.zeros((8, 16))
    d = jnp.ones(16)
    got = float(M.proxy_loss_pallas(a, b, wp, mask, w_bar, d))
    want = float(jnp.sum(wp * wp))
    np.testing.assert_allclose(got, want, rtol=1e-4)


def test_mask_init_lowers():
    lowered, ins, outs = aot.lower_mask_init(8, 16)
    assert "ENTRY" in aot.to_hlo_text(lowered)
    assert outs == [[8, 16]]


def test_gpt_nll_lowers_with_param_names():
    lowered, ins, outs, names = aot.lower_gpt_nll(CFG, 2, 16)
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text
    assert outs == [[2]]
    assert names == sorted(names)
    assert "tok_embed" in names


def test_prunable_shapes_unique_sorted():
    shapes = aot.prunable_shapes({"d_model": 128, "d_ff": 512})
    assert shapes == [(128, 128), (128, 512), (512, 128)]
