"""Layer-2 ARMOR optimizer tests: descent, mask freezing, kernel-evaluated
loss consistency — the Python-side mirror of the Rust optimizer invariants."""

import pytest

pytest.importorskip("jax", reason="JAX/Pallas not installed (bare runner)")

import jax
import jax.numpy as jnp
import numpy as np

from compile import model as M
from compile.kernels import ref


def setup(seed=0, d_out=16, d_in=32, db=8):
    key = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    w = jax.random.normal(k1, (d_out, d_in))
    d = jnp.abs(jax.random.normal(k2, (d_in,))) + 0.1
    w_bar, _, _ = ref.nowag_normalize_ref(w)
    imp = w_bar * w_bar * d[None, :]
    mask = ref.mask_topk_nm_ref(imp, 2, 4)
    nbo, nbi = d_out // db, d_in // db
    a = jnp.broadcast_to(jnp.eye(db), (nbo, db, db)).copy()
    b = jnp.broadcast_to(jnp.eye(db), (nbi, db, db)).copy()
    zeros = lambda x: jnp.zeros_like(x)
    state = dict(a=a, b=b, wp=w_bar, mask=mask, w_bar=w_bar, d=d,
                 ma=zeros(a), va=zeros(a), mb=zeros(b), vb=zeros(b),
                 mw=zeros(w_bar), vw=zeros(w_bar))
    return state


def run_steps(state, k_steps, lr=5e-3, rounds=1):
    t = jnp.zeros(())
    loss = None
    for _ in range(rounds):
        out = M.armor_cont_steps(
            state["a"], state["b"], state["wp"], state["mask"], state["w_bar"],
            state["d"], state["ma"], state["va"], state["mb"], state["vb"],
            state["mw"], state["vw"], t, jnp.asarray(lr, jnp.float32),
            k_steps=k_steps,
        )
        (state["a"], state["b"], state["wp"], state["ma"], state["va"],
         state["mb"], state["vb"], state["mw"], state["vw"], t, loss) = out
    return state, float(loss)


def test_cont_steps_reduce_loss():
    state = setup()
    init_loss = float(M.proxy_loss_jnp(state["a"], state["b"], state["wp"],
                                       state["mask"], state["w_bar"], state["d"]))
    state, loss = run_steps(state, k_steps=10, rounds=10)
    assert loss < 0.9 * init_loss, (init_loss, loss)


def test_masked_entries_do_not_move():
    state = setup(seed=1)
    wp0 = state["wp"]
    state, _ = run_steps(state, k_steps=5, rounds=2)
    frozen = (state["mask"] == 0)
    np.testing.assert_allclose(
        np.asarray(state["wp"])[np.asarray(frozen)],
        np.asarray(wp0)[np.asarray(frozen)],
        atol=0,
    )


def test_pallas_loss_matches_jnp_loss():
    state = setup(seed=2)
    state, loss_pallas = run_steps(state, k_steps=3)
    loss_jnp = float(M.proxy_loss_jnp(state["a"], state["b"], state["wp"],
                                      state["mask"], state["w_bar"], state["d"]))
    np.testing.assert_allclose(loss_pallas, loss_jnp, rtol=1e-4)


def test_init_mask_is_nowag_optimal():
    """Any other 2:4 mask on W̄ with identity wrappers has ≥ proxy loss."""
    state = setup(seed=3)
    base = float(M.proxy_loss_jnp(state["a"], state["b"], state["wp"],
                                  state["mask"], state["w_bar"], state["d"]))
    key = jax.random.PRNGKey(9)
    for i in range(20):
        key, k = jax.random.split(key)
        rand_imp = jax.random.normal(k, state["w_bar"].shape)
        alt = ref.mask_topk_nm_ref(rand_imp, 2, 4)
        alt_loss = float(M.proxy_loss_jnp(state["a"], state["b"], state["wp"],
                                          alt, state["w_bar"], state["d"]))
        assert alt_loss >= base - 1e-6


def test_normalize_matches_rust_semantics():
    key = jax.random.PRNGKey(4)
    w = jax.random.normal(key, (8, 12))
    w_bar, r1, r2 = ref.nowag_normalize_ref(w)
    # rows of w_bar unit-norm; denormalization recovers w
    np.testing.assert_allclose(jnp.sum(w_bar**2, axis=1), jnp.ones(8), rtol=1e-4)
    np.testing.assert_allclose(w_bar * r2[:, None] * r1[None, :], w, rtol=1e-4)
